//! `hasfl serve` — the long-running multi-tenant training daemon
//! (DESIGN.md §12).
//!
//! The daemon exposes the [`crate::experiment`] API over HTTP: create
//! sessions from JSON configs, run/step them, stream their
//! [`crate::experiment::RoundReport`]s as NDJSON, checkpoint on demand,
//! and list/inspect/delete them — many experiments multiplexed through one
//! process, one bounded worker pool, and one engine-lane budget.
//!
//! # Architecture
//!
//! ```text
//!  HTTP conn threads ──commands──▶ per-session mpsc ──▶ SessionDriver
//!        │                                                  │ owned by
//!        │        job queue (session ids)                   ▼
//!        └──kick──▶ [JobQueue] ──pop──▶ worker pool (N threads, pump loop)
//!                                                           │
//!        readers ◀──tail by offset── [EventLog] ◀──events───┘
//! ```
//!
//! Every session lives in a `SessionSlot`: the driver sits in a mutexed
//! `Option` that exactly one worker takes while pumping; commands enqueue
//! onto the session's channel and *kick* the job queue, so an idle session
//! costs nothing and a busy one absorbs new commands between rounds. The
//! kick counter (`SessionSlot::kicks`) closes the classic lost-wakeup
//! race: a worker about to park the driver re-checks it and re-enqueues
//! the job if a command slipped in after its final drain.
//!
//! # Restart protocol
//!
//! Sessions survive daemon restarts. On graceful shutdown (SIGINT/SIGTERM
//! or `POST /shutdown`) every live session is checkpointed into its state
//! directory via the `HASFLCKP` machinery (DESIGN.md §10); a daemon
//! started on the same `--state-dir` re-adopts each `session_*` directory
//! by resuming its newest valid checkpoint (older ones are fallbacks
//! against torn files), so resumed histories are bit-identical to
//! uninterrupted runs.
//!
//! # Overload and fault posture (DESIGN.md §13)
//!
//! The daemon assumes hostile or broken clients: connections are capped
//! ([`ServeConfig::max_conns`], excess answered `503` at the door), every
//! socket gets read *and* write timeouts ([`ServeConfig::io_timeout`], so
//! a slow-loris sender or a non-reading receiver cannot pin a thread),
//! and run/step kicks shed with `503` once the job queue reaches
//! [`ServeConfig::queue_cap`]. Request handling never unwraps: the whole
//! module denies `clippy::unwrap_used`, and lock poisoning (a panicking
//! holder) is recovered via `lock` instead of cascading.

// A panicking connection thread must never take the daemon with it, and a
// poisoned mutex must not cascade: every fallible path returns an HTTP
// error or recovers instead of unwrapping.
#![deny(clippy::unwrap_used)]

mod api;
mod http;
mod queue;

pub use api::{engine_smoke, engine_stats_json, info_json};
pub use queue::{event_json, EventLog, JobQueue, LogState};

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::backend::BackendKind;
use crate::checkpoint::CheckpointObserver;
use crate::config::Config;
use crate::experiment::{
    DriverCommand, EventBridge, EventSink, Experiment, ExperimentBuilder, Preset, Pump,
    SessionDriver,
};
use crate::metrics::History;
use crate::util::Json;

/// Default cap on simultaneously open HTTP connections (`--max-conns`).
pub const DEFAULT_MAX_CONNS: usize = 64;

/// Default job-queue depth at which run/step kicks are refused with `503`
/// (`--queue-cap`).
pub const DEFAULT_QUEUE_CAP: usize = 256;

/// Lock a mutex, recovering the data from a poisoned lock. Serve mutexes
/// guard plain registry data (session maps, parked drivers, command
/// senders) that stays structurally consistent even if a holder panicked,
/// and the daemon must keep serving after any one connection or worker
/// thread dies — so poison is survivable, not fatal.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// How the daemon binds and where it keeps session state.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Listen address; port 0 picks a free port (the bound address is
    /// written to `<state_dir>/daemon.addr` either way).
    pub addr: String,
    /// Session state root: one `session_NNNNNN/` directory per session
    /// (meta.json + checkpoints), adopted on restart.
    pub state_dir: PathBuf,
    /// Session-worker pool size (sessions stepped concurrently).
    pub workers: usize,
    /// AOT-artifacts directory (PJRT backend; native needs none).
    pub artifacts: PathBuf,
    /// Cap on simultaneously open HTTP connections; excess connections
    /// are answered `503` and closed at the door instead of piling up
    /// threads under overload.
    pub max_conns: usize,
    /// Per-connection socket read *and* write timeout: a slow-loris
    /// sender (or a client that stops reading its response) is cut off
    /// after this long instead of pinning a connection thread forever.
    /// Zero disables both timeouts.
    pub io_timeout: Duration,
    /// Job-queue depth at which run/step kicks are refused with `503`
    /// (control commands — pause, checkpoint, close — always enqueue).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:4780".into(),
            state_dir: PathBuf::from("serve-state"),
            workers: 2,
            artifacts: PathBuf::from("artifacts"),
            max_conns: DEFAULT_MAX_CONNS,
            io_timeout: Duration::from_secs(10),
            queue_cap: DEFAULT_QUEUE_CAP,
        }
    }
}

/// One hosted session: registry entry + driver parking spot + event log.
struct SessionSlot {
    id: u64,
    name: String,
    dir: PathBuf,
    /// Command channel into the driver.
    cmd: Mutex<Sender<DriverCommand>>,
    /// The driver parks here while no worker is pumping it.
    driver: Mutex<Option<SessionDriver>>,
    /// Bumped on every enqueued command; workers compare before/after
    /// parking the driver to close the lost-wakeup race.
    kicks: AtomicU64,
    log: Arc<EventLog>,
    /// Canonical config of the built session (resolved backend included).
    config: Json,
    rounds_budget: usize,
    checkpoint_every: Option<usize>,
    keep_last: usize,
    concurrent: bool,
}

impl SessionSlot {
    /// Queue a command and kick the worker pool. Duplicate kicks are
    /// harmless; a missing kick would strand the command, so every
    /// enqueue kicks.
    fn enqueue(&self, core: &Core, cmd: DriverCommand) {
        let _ = lock(&self.cmd).send(cmd);
        self.kicks.fetch_add(1, Ordering::SeqCst);
        core.jobs.push(self.id);
    }

    /// [`SessionSlot::enqueue`] with backpressure: refuses (returns
    /// `false`) when the job queue already holds [`Core::queue_cap`]
    /// unclaimed kicks, so run/step traffic sheds with `503` instead of
    /// growing the queue without bound. Control commands (pause,
    /// checkpoint, close) keep using plain `enqueue` — refusing those
    /// could strand a session. The depth check races benignly with
    /// concurrent pushes: the cap is a shed threshold, not an exact
    /// bound, and the kick invariant (command sent ⟹ job pushed) holds
    /// on both sides of it.
    fn try_enqueue(&self, core: &Core, cmd: DriverCommand) -> bool {
        if core.jobs.depth() >= core.queue_cap {
            return false;
        }
        self.enqueue(core, cmd);
        true
    }

    fn summary(&self) -> Json {
        self.log.with(|s| {
            let mut j = Json::obj();
            j.set("id", Json::Num(self.id as f64))
                .set("name", Json::Str(self.name.clone()))
                .set("round", Json::Num(s.round as f64))
                .set("rounds", Json::Num(self.rounds_budget as f64))
                .set("done", Json::Bool(s.done))
                .set("closed", Json::Bool(s.closed))
                .set("checkpoints", Json::Num(s.checkpoints.len() as f64))
                .set("events", Json::Num(s.events.len() as f64));
            match &s.last_error {
                Some(e) => j.set("last_error", Json::Str(e.clone())),
                None => j.set("last_error", Json::Null),
            };
            j
        })
    }
}

/// Shared daemon state.
struct Core {
    state_dir: PathBuf,
    artifacts: PathBuf,
    workers: usize,
    sessions: Mutex<BTreeMap<u64, Arc<SessionSlot>>>,
    next_id: AtomicU64,
    jobs: JobQueue,
    /// The daemon is tearing down: accept loop and workers exit, event
    /// followers unblock.
    shutdown: AtomicBool,
    /// `POST /shutdown` was called; the owner (CLI loop or test) should
    /// call [`Daemon::stop`].
    shutdown_requested: AtomicBool,
    /// Cached `info` payload (computed once at startup).
    info: Json,
    /// Connection cap ([`ServeConfig::max_conns`]).
    max_conns: usize,
    /// Socket read/write timeout ([`ServeConfig::io_timeout`]).
    io_timeout: Duration,
    /// Job-queue shed threshold ([`ServeConfig::queue_cap`]).
    queue_cap: usize,
    /// HTTP connections currently open (sheds at `max_conns`).
    live_conns: AtomicUsize,
}

/// A running daemon. Dropping it (or calling [`Daemon::stop`]) performs
/// the graceful shutdown: stop accepting, drain workers, checkpoint and
/// close every live session.
pub struct Daemon {
    core: Arc<Core>,
    addr: SocketAddr,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Daemon {
    /// Bind, adopt any sessions left in the state directory, and start
    /// the worker pool and accept loop.
    pub fn start(cfg: ServeConfig) -> crate::Result<Daemon> {
        std::fs::create_dir_all(&cfg.state_dir)?;
        let kind = BackendKind::from_env()
            .unwrap_or(BackendKind::Auto)
            .resolve(&cfg.artifacts);
        let info = api::info_json(kind, &cfg.artifacts)?;
        let workers_n = cfg.workers.max(1);
        let core = Arc::new(Core {
            state_dir: cfg.state_dir.clone(),
            artifacts: cfg.artifacts.clone(),
            workers: workers_n,
            sessions: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            jobs: JobQueue::new(),
            shutdown: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            info,
            max_conns: cfg.max_conns.max(1),
            io_timeout: cfg.io_timeout,
            queue_cap: cfg.queue_cap.max(1),
            live_conns: AtomicUsize::new(0),
        });
        adopt_sessions(&core);

        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| anyhow::anyhow!("cannot bind '{}': {e}", cfg.addr))?;
        let addr = listener.local_addr()?;
        std::fs::write(cfg.state_dir.join("daemon.addr"), format!("{addr}\n"))?;
        listener.set_nonblocking(true)?;

        let workers = (0..workers_n)
            .map(|_| {
                let core = core.clone();
                std::thread::spawn(move || worker_loop(&core))
            })
            .collect();
        let accept = {
            let core = core.clone();
            Some(std::thread::spawn(move || accept_loop(&core, &listener)))
        };
        Ok(Daemon { core, addr, accept, workers })
    }

    /// The bound listen address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether a client asked the daemon to shut down (`POST /shutdown`).
    pub fn shutdown_requested(&self) -> bool {
        self.core.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Live (non-closed) session count.
    pub fn live_sessions(&self) -> usize {
        let slots: Vec<_> = lock(&self.core.sessions).values().cloned().collect();
        slots.iter().filter(|s| !s.log.with(|l| l.closed)).count()
    }

    /// Graceful shutdown: stop accepting, drain the worker pool, then
    /// checkpoint and close every live session (the restart protocol's
    /// write half).
    pub fn stop(mut self) -> crate::Result<()> {
        self.shutdown_impl();
        Ok(())
    }

    fn shutdown_impl(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.core.shutdown.store(true, Ordering::SeqCst);
        let slots: Vec<_> = lock(&self.core.sessions).values().cloned().collect();
        for slot in &slots {
            slot.log.nudge();
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for _ in 0..self.workers.len() {
            self.core.jobs.push_stop();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Workers are gone, so every non-closed driver is parked. Close
        // each one inline: checkpoint at the current round, flush
        // observers, shut the engine down.
        for slot in &slots {
            if slot.log.with(|s| s.closed) {
                continue;
            }
            let Some(mut driver) = lock(&slot.driver).take() else {
                eprintln!("serve: session {} has no parked driver at shutdown", slot.id);
                continue;
            };
            let _ = lock(&slot.cmd).send(DriverCommand::Close { checkpoint: true });
            loop {
                match driver.pump() {
                    Pump::Worked => continue,
                    Pump::Closed | Pump::Idle => break,
                }
            }
        }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

// ---------------------------------------------------------------------------
// Worker pool
// ---------------------------------------------------------------------------

fn worker_loop(core: &Arc<Core>) {
    while let Some(id) = core.jobs.pop() {
        let slot = lock(&core.sessions).get(&id).cloned();
        let Some(slot) = slot else { continue };
        // Another worker is already pumping this session: it will drain
        // whatever command triggered this job (or re-kick on its way out).
        let taken = lock(&slot.driver).take();
        let Some(mut driver) = taken else { continue };
        loop {
            if core.shutdown.load(Ordering::SeqCst) {
                *lock(&slot.driver) = Some(driver);
                break;
            }
            let kicks_before = slot.kicks.load(Ordering::SeqCst);
            match driver.pump() {
                Pump::Worked => continue,
                Pump::Closed => break, // terminal; the log got the Closed event
                Pump::Idle => {
                    if slot.kicks.load(Ordering::SeqCst) != kicks_before {
                        continue; // a command landed during the pump
                    }
                    *lock(&slot.driver) = Some(driver);
                    // A command may have slipped in between the check above
                    // and parking the driver — and its job may already have
                    // bounced off the empty slot. Re-kick to cover it.
                    if slot.kicks.load(Ordering::SeqCst) != kicks_before {
                        core.jobs.push(id);
                    }
                    break;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Session creation / adoption
// ---------------------------------------------------------------------------

/// Engine lanes for a session that didn't pick: share host parallelism
/// across the worker pool (width is wall-clock-only; numerics are
/// identical at any width — `rust/tests/parity_modes.rs`).
fn default_lanes(workers: usize) -> usize {
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    (cores / workers.max(1)).max(1)
}

/// Build, register, and park a session. Shared by HTTP create and restart
/// adoption; `builder` arrives preset with the config or resume source.
fn register_slot(
    core: &Arc<Core>,
    id: u64,
    name: String,
    mut builder: ExperimentBuilder,
    checkpoint_every: Option<usize>,
    keep_last: usize,
    concurrent: bool,
) -> crate::Result<Arc<SessionSlot>> {
    let dir = core.state_dir.join(format!("session_{id:06}"));
    std::fs::create_dir_all(&dir)?;
    let log = Arc::new(EventLog::default());
    let sink: EventSink = {
        let log = log.clone();
        Arc::new(move |e| log.absorb(&e))
    };
    if let Some(every) = checkpoint_every {
        builder = builder.observer(Box::new(CheckpointObserver::new(&dir, every).keep_last(keep_last)));
    }
    builder = builder
        .observer(Box::new(EventBridge::new(sink.clone())))
        .artifacts(&core.artifacts)
        .concurrent(concurrent);
    let session = builder.build()?;
    let config = session.config().to_json();
    let rounds_budget = session.config().train.rounds;
    log.with(|s| {
        // Adopted sessions restore mid-run: seed the live mirrors so
        // /history.csv and /wait see the restored rounds, and rebuild the
        // report backlog so `GET /reports?from=K` never silently loses
        // rounds a client saw before the restart. Full RoundReports are
        // not checkpointed, so restored entries carry the per-round
        // history fields plus `"restored": true` to tell them apart; the
        // report list and history.csv stay index-aligned either way.
        s.records = session.history().records.clone();
        s.round = session.round();
        s.done = session.is_done();
        s.reports = s
            .records
            .iter()
            .map(|r| {
                let mut j = Json::obj();
                j.set("round", Json::Num(r.round as f64))
                    .set("sim_time", Json::Num(r.sim_time))
                    .set("loss", Json::Num(r.loss))
                    .set("test_acc", r.test_acc.map_or(Json::Null, Json::Num))
                    .set("restored", Json::Bool(true));
                j
            })
            .collect();
    });
    let (driver, cmd) = SessionDriver::new(session, sink);
    let driver = driver.checkpoint_dir(&dir);
    let slot = Arc::new(SessionSlot {
        id,
        name,
        dir,
        cmd: Mutex::new(cmd),
        driver: Mutex::new(Some(driver)),
        kicks: AtomicU64::new(0),
        log,
        config,
        rounds_budget,
        checkpoint_every,
        keep_last,
        concurrent,
    });
    lock(&core.sessions).insert(id, slot.clone());
    Ok(slot)
}

fn write_meta(slot: &SessionSlot) -> crate::Result<()> {
    let mut meta = Json::obj();
    meta.set("name", Json::Str(slot.name.clone()))
        .set("config", slot.config.clone())
        .set(
            "checkpoint_every",
            slot.checkpoint_every.map_or(Json::Null, |n| Json::Num(n as f64)),
        )
        .set("keep_last", Json::Num(slot.keep_last as f64))
        .set("concurrent", Json::Bool(slot.concurrent));
    std::fs::write(slot.dir.join("meta.json"), meta.dump())?;
    Ok(())
}

/// Create a session from an HTTP request body. Returns the registered
/// slot plus the requested initial run kick (the `run` field), which the
/// caller enqueues subject to queue backpressure.
fn create_session(
    core: &Arc<Core>,
    body: &Json,
) -> crate::Result<(Arc<SessionSlot>, Option<usize>)> {
    fn opt_usize(body: &Json, key: &str) -> crate::Result<Option<usize>> {
        match body.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v
                .as_usize()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("request field '{key}': {e}")),
        }
    }
    let id = core.next_id.fetch_add(1, Ordering::SeqCst);
    let name = match body.get("name") {
        Some(v) => v
            .as_str()
            .map_err(|e| anyhow::anyhow!("request field 'name': {e}"))?
            .to_string(),
        None => format!("session-{id:06}"),
    };
    let mut builder = match body.get("config") {
        Some(cfg) => Experiment::builder().config(Config::from_json(cfg)?),
        None => {
            let preset = match body.get("preset") {
                Some(v) => v.as_str().map_err(|e| anyhow::anyhow!("request field 'preset': {e}"))?,
                None => "small",
            };
            Experiment::builder().preset(Preset::parse(preset)?)
        }
    };
    if let Some(n) = opt_usize(body, "devices")? {
        builder = builder.devices(n);
    }
    if let Some(n) = opt_usize(body, "rounds")? {
        builder = builder.rounds(n);
    }
    // Buffered-asynchronous rounds (docs/ASYNC.md): sets the buffer size
    // only, so a full "async" section in the request's `config` keeps its
    // max_staleness and decay.
    if let Some(k) = opt_usize(body, "async_buffer")? {
        builder = builder.tune(move |c| {
            let mut spec = c.async_spec.clone().unwrap_or_default();
            spec.buffer_k = k;
            c.async_spec = Some(spec);
        });
    }
    if let Some(v) = body.get("seed") {
        let seed = match v {
            Json::Str(s) => s
                .parse::<u64>()
                .map_err(|e| anyhow::anyhow!("request field 'seed': {e}"))?,
            other => other
                .as_u64()
                .map_err(|e| anyhow::anyhow!("request field 'seed': {e}"))?,
        };
        builder = builder.seed(seed);
    }
    if let Some(v) = body.get("strategy") {
        let s = v.as_str().map_err(|e| anyhow::anyhow!("request field 'strategy': {e}"))?;
        builder = builder.strategy(crate::config::StrategyKind::parse(s)?);
    }
    let concurrent = match body.get("concurrent") {
        Some(v) => v
            .as_bool()
            .map_err(|e| anyhow::anyhow!("request field 'concurrent': {e}"))?,
        None => false,
    };
    let checkpoint_every = opt_usize(body, "checkpoint_every")?;
    if let Some(every) = checkpoint_every {
        anyhow::ensure!(every >= 1, "request field 'checkpoint_every': must be >= 1");
    }
    let keep_last = opt_usize(body, "keep_last")?.unwrap_or(3);
    // Engine-lane budget: an explicit request wins; a config that left the
    // pool on auto gets the daemon's fair share instead of grabbing every
    // core per session.
    let lanes_req = opt_usize(body, "engine_pool")?;
    let fair_share = default_lanes(core.workers);
    builder = builder.tune(move |c| match lanes_req {
        Some(p) => c.engine_pool = p,
        None if c.engine_pool == 0 => c.engine_pool = fair_share,
        None => {}
    });

    // Validate the run kick before building anything; the caller issues
    // it (with backpressure) once the session is registered.
    let run = opt_usize(body, "run")?;
    let slot = register_slot(core, id, name, builder, checkpoint_every, keep_last, concurrent)?;
    write_meta(&slot)?;
    Ok((slot, run))
}

/// Re-adopt every `session_NNNNNN/` directory in the state dir.
fn adopt_sessions(core: &Arc<Core>) {
    let Ok(entries) = std::fs::read_dir(&core.state_dir) else { return };
    for entry in entries.flatten() {
        let file_name = entry.file_name().to_string_lossy().into_owned();
        let Some(id_str) = file_name.strip_prefix("session_") else { continue };
        let Ok(id) = id_str.parse::<u64>() else { continue };
        if !entry.path().is_dir() {
            continue;
        }
        match adopt_one(core, id, &entry.path()) {
            Ok(slot) => {
                let round = slot.log.with(|s| s.round);
                eprintln!("serve: adopted session {id} '{}' at round {round}", slot.name);
            }
            Err(e) => eprintln!("serve: cannot adopt '{}': {e:#}", entry.path().display()),
        }
    }
    let max_id = lock(&core.sessions).keys().max().copied().unwrap_or(0);
    core.next_id.store(max_id + 1, Ordering::SeqCst);
}

/// Adopt one session directory: resume its newest valid checkpoint,
/// falling back to older ones against torn files, then to a fresh build
/// from the meta config (round 0) when no checkpoint is usable.
fn adopt_one(core: &Arc<Core>, id: u64, dir: &std::path::Path) -> crate::Result<Arc<SessionSlot>> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))?;
    let meta = Json::parse(&meta_text)?;
    let name = meta.req("name")?.as_str()?.to_string();
    let checkpoint_every = match meta.get("checkpoint_every") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_usize()?),
    };
    let keep_last = match meta.get("keep_last") {
        Some(v) => v.as_usize()?,
        None => 3,
    };
    let concurrent = match meta.get("concurrent") {
        Some(v) => v.as_bool()?,
        None => false,
    };
    let mut ckpts: Vec<PathBuf> = std::fs::read_dir(dir)?
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("ckpt_round_") && n.ends_with(".hckpt"))
        })
        .collect();
    ckpts.sort(); // zero-padded round numbers sort chronologically
    for ckpt in ckpts.iter().rev() {
        let builder = Experiment::builder().resume_from(ckpt);
        match register_slot(
            core,
            id,
            name.clone(),
            builder,
            checkpoint_every,
            keep_last,
            concurrent,
        ) {
            Ok(slot) => return Ok(slot),
            Err(e) => {
                eprintln!("serve: checkpoint '{}' unusable: {e:#}", ckpt.display());
            }
        }
    }
    // No usable checkpoint: the session never progressed far enough to
    // write one. Rebuild from the recorded config at round 0.
    let cfg = Config::from_json(meta.req("config")?)?;
    register_slot(
        core,
        id,
        name,
        Experiment::builder().config(cfg),
        checkpoint_every,
        keep_last,
        concurrent,
    )
}

// ---------------------------------------------------------------------------
// HTTP surface
// ---------------------------------------------------------------------------

fn accept_loop(core: &Arc<Core>, listener: &TcpListener) {
    loop {
        if core.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let _ = stream.set_nonblocking(false);
                if core.io_timeout > Duration::ZERO {
                    // Read AND write timeouts: a slow-loris sender stalls
                    // in read_request, a non-reading client stalls the
                    // response write — both release the thread here.
                    let _ = stream.set_read_timeout(Some(core.io_timeout));
                    let _ = stream.set_write_timeout(Some(core.io_timeout));
                }
                let live = core.live_conns.fetch_add(1, Ordering::SeqCst) + 1;
                if live > core.max_conns {
                    // Shed at the door: answer 503 from the accept thread
                    // (bounded by the write timeout) instead of spawning
                    // yet another connection thread under overload.
                    core.live_conns.fetch_sub(1, Ordering::SeqCst);
                    let _ = http::respond_error(
                        &mut stream,
                        503,
                        "connection limit reached; retry shortly",
                    );
                    continue;
                }
                let core = core.clone();
                std::thread::spawn(move || {
                    // Decrement on every exit path, panics included, or a
                    // single bad connection would leak a slot forever.
                    struct ConnSlot(Arc<Core>);
                    impl Drop for ConnSlot {
                        fn drop(&mut self) {
                            self.0.live_conns.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                    let _slot = ConnSlot(core.clone());
                    handle_conn(&core, stream);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(25)),
        }
    }
}

fn handle_conn(core: &Arc<Core>, mut stream: TcpStream) {
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let _ = http::respond_error(&mut stream, 400, &format!("bad request: {e:#}"));
            return;
        }
    };
    if let Err(e) = route(core, &req, &mut stream) {
        // Best-effort: the response head may already be on the wire.
        let _ = http::respond_error(&mut stream, 500, &format!("{e:#}"));
    }
}

fn lookup(core: &Core, id_str: &str) -> Option<Arc<SessionSlot>> {
    let id: u64 = id_str.parse().ok()?;
    lock(&core.sessions).get(&id).cloned()
}

fn route(core: &Arc<Core>, req: &http::Request, stream: &mut TcpStream) -> crate::Result<()> {
    let segments = req.segments();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", []) => {
            let mut j = Json::obj();
            j.set("service", Json::Str("hasfl".into())).set(
                "endpoints",
                Json::Arr(
                    [
                        "GET /healthz",
                        "GET /info",
                        "GET /sessions",
                        "POST /sessions",
                        "GET /sessions/:id",
                        "DELETE /sessions/:id",
                        "POST /sessions/:id/run",
                        "POST /sessions/:id/step",
                        "POST /sessions/:id/pause",
                        "POST /sessions/:id/checkpoint",
                        "GET /sessions/:id/reports",
                        "GET /sessions/:id/events",
                        "GET /sessions/:id/history.csv",
                        "GET /sessions/:id/config",
                        "GET /sessions/:id/wait",
                        "POST /shutdown",
                    ]
                    .iter()
                    .map(|s| Json::Str(s.to_string()))
                    .collect(),
                ),
            );
            http::respond_json(stream, 200, &j)
        }
        ("GET", ["healthz"]) => {
            let mut j = core.info.clone();
            let slots: Vec<_> = lock(&core.sessions).values().cloned().collect();
            let live = slots.iter().filter(|s| !s.log.with(|l| l.closed)).count();
            j.set("status", Json::Str("ok".into()))
                .set("sessions", Json::Num(live as f64))
                .set("workers", Json::Num(core.workers as f64))
                .set("jobs", Json::Num(core.jobs.depth() as f64))
                .set("live_conns", Json::Num(core.live_conns.load(Ordering::SeqCst) as f64))
                .set("max_conns", Json::Num(core.max_conns as f64));
            http::respond_json(stream, 200, &j)
        }
        ("GET", ["info"]) => http::respond_json(stream, 200, &core.info),
        ("GET", ["sessions"]) => {
            let slots: Vec<_> = lock(&core.sessions).values().cloned().collect();
            let list = Json::Arr(slots.iter().map(|s| s.summary()).collect());
            let mut j = Json::obj();
            j.set("sessions", list);
            http::respond_json(stream, 200, &j)
        }
        ("POST", ["sessions"]) => {
            let body = match req.json_body() {
                Ok(b) => b,
                Err(e) => return http::respond_error(stream, 400, &format!("{e:#}")),
            };
            match create_session(core, &body) {
                Ok((slot, run)) => {
                    let mut j = slot.summary();
                    if let Some(n) = run {
                        // The session exists either way; a saturated queue
                        // only refuses the initial kick, and the client
                        // re-issues it via POST /sessions/:id/run.
                        let queued = slot.try_enqueue(core, DriverCommand::Run(n));
                        j.set("run_enqueued", Json::Bool(queued));
                    }
                    http::respond_json(stream, 201, &j)
                }
                Err(e) => http::respond_error(stream, 400, &format!("{e:#}")),
            }
        }
        ("GET", ["sessions", id]) => match lookup(core, id) {
            Some(slot) => http::respond_json(stream, 200, &slot.summary()),
            None => http::respond_error(stream, 404, &format!("no session '{id}'")),
        },
        ("DELETE", ["sessions", id]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            if !slot.log.with(|s| s.closed) {
                slot.enqueue(core, DriverCommand::Close { checkpoint: false });
                let closed = slot.log.wait_until(Duration::from_secs(60), |s| s.closed);
                if !closed {
                    return http::respond_error(
                        stream,
                        500,
                        "session did not close within 60s; try again",
                    );
                }
            }
            lock(&core.sessions).remove(&slot.id);
            let _ = std::fs::remove_dir_all(&slot.dir);
            let mut j = Json::obj();
            j.set("deleted", Json::Num(slot.id as f64));
            http::respond_json(stream, 200, &j)
        }
        ("POST", ["sessions", id, "run"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            if slot.log.with(|s| s.closed) {
                return http::respond_error(stream, 409, "session is closed");
            }
            let body = match req.json_body() {
                Ok(b) => b,
                Err(e) => return http::respond_error(stream, 400, &format!("{e:#}")),
            };
            let rounds = match body.get("rounds") {
                Some(v) => match v.as_usize() {
                    Ok(n) => n,
                    Err(e) => {
                        return http::respond_error(
                            stream,
                            400,
                            &format!("request field 'rounds': {e}"),
                        )
                    }
                },
                // Default: run out the remaining budget.
                None => {
                    let round = slot.log.with(|s| s.round);
                    slot.rounds_budget.saturating_sub(round)
                }
            };
            if !slot.try_enqueue(core, DriverCommand::Run(rounds)) {
                return http::respond_error(stream, 503, "job queue is full; retry shortly");
            }
            let mut j = slot.summary();
            j.set("enqueued_rounds", Json::Num(rounds as f64));
            http::respond_json(stream, 202, &j)
        }
        ("POST", ["sessions", id, "step"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            if slot.log.with(|s| s.closed) {
                return http::respond_error(stream, 409, "session is closed");
            }
            if !slot.try_enqueue(core, DriverCommand::Run(1)) {
                return http::respond_error(stream, 503, "job queue is full; retry shortly");
            }
            http::respond_json(stream, 202, &slot.summary())
        }
        ("POST", ["sessions", id, "pause"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            slot.enqueue(core, DriverCommand::Pause);
            http::respond_json(stream, 202, &slot.summary())
        }
        ("POST", ["sessions", id, "checkpoint"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            if slot.log.with(|s| s.closed) {
                return http::respond_error(stream, 409, "session is closed");
            }
            let before = slot.log.with(|s| s.events.len());
            slot.enqueue(core, DriverCommand::Checkpoint(None));
            // Wait for the write (or an error event) so the client gets the
            // path back; checkpoints execute at the next round boundary.
            let ok = slot.log.wait_until(Duration::from_secs(120), |s| {
                s.events[before..].iter().any(|e| {
                    matches!(
                        e.get("type").and_then(|t| t.as_str().ok()),
                        Some("checkpointed") | Some("error")
                    )
                })
            });
            if !ok {
                return http::respond_error(stream, 408, "checkpoint did not complete in 120s");
            }
            let mut j = slot.summary();
            let path = slot.log.with(|s| {
                s.events[before..]
                    .iter()
                    .rev()
                    .find(|e| {
                        e.get("type").and_then(|t| t.as_str().ok()) == Some("checkpointed")
                    })
                    .and_then(|e| e.get("path").and_then(|p| p.as_str().ok()).map(String::from))
            });
            match path {
                Some(p) => j.set("checkpoint", Json::Str(p)),
                None => j.set("checkpoint", Json::Null),
            };
            http::respond_json(stream, 200, &j)
        }
        ("GET", ["sessions", id, "reports"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            let from = req.query_opt::<usize>("from")?.unwrap_or(0);
            let reports =
                slot.log.with(|s| s.reports.get(from..).unwrap_or(&[]).to_vec());
            let mut j = Json::obj();
            j.set("from", Json::Num(from as f64)).set("reports", Json::Arr(reports));
            http::respond_json(stream, 200, &j)
        }
        ("GET", ["sessions", id, "events"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            stream_events(core, &slot, req, stream)
        }
        ("GET", ["sessions", id, "history.csv"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            let history = History { records: slot.log.with(|s| s.records.clone()) };
            http::respond(stream, 200, "text/csv", history.to_csv_string().as_bytes())
        }
        ("GET", ["sessions", id, "config"]) => match lookup(core, id) {
            Some(slot) => http::respond_json(stream, 200, &slot.config),
            None => http::respond_error(stream, 404, &format!("no session '{id}'")),
        },
        ("GET", ["sessions", id, "wait"]) => {
            let Some(slot) = lookup(core, id) else {
                return http::respond_error(stream, 404, &format!("no session '{id}'"));
            };
            let target = req.query_opt::<usize>("round")?.unwrap_or(slot.rounds_budget);
            let timeout_ms = req.query_opt::<u64>("timeout_ms")?.unwrap_or(60_000).min(600_000);
            let satisfied = slot.log.wait_until(Duration::from_millis(timeout_ms), |s| {
                s.round >= target || s.closed || s.done || s.last_error.is_some()
            });
            let mut j = slot.summary();
            j.set("satisfied", Json::Bool(satisfied));
            http::respond_json(stream, if satisfied { 200 } else { 408 }, &j)
        }
        ("POST", ["shutdown"]) => {
            core.shutdown_requested.store(true, Ordering::SeqCst);
            let mut j = Json::obj();
            j.set("status", Json::Str("shutting-down".into()));
            http::respond_json(stream, 200, &j)
        }
        (_, ["sessions", ..]) | (_, ["healthz"]) | (_, ["info"]) | (_, ["shutdown"]) => {
            http::respond_error(stream, 405, "method not allowed")
        }
        _ => http::respond_error(stream, 404, &format!("no route for '{}'", req.path)),
    }
}

/// `GET /sessions/:id/events[?from=K&follow=1]` — NDJSON event stream.
/// Without `follow` it returns the backlog from `from` and closes; with
/// `follow` it tails the log until the session closes, the daemon shuts
/// down, or the client hangs up.
fn stream_events(
    core: &Arc<Core>,
    slot: &Arc<SessionSlot>,
    req: &http::Request,
    stream: &mut TcpStream,
) -> crate::Result<()> {
    use std::io::Write as _;
    let mut offset = req.query_opt::<usize>("from")?.unwrap_or(0);
    let follow = req.query_opt::<usize>("follow")?.unwrap_or(0) != 0;
    http::start_stream(stream, "application/x-ndjson")?;
    loop {
        let (tail, closed) = slot.log.events_from(offset);
        offset += tail.len();
        for event in &tail {
            // A write error means the client hung up; stop quietly.
            if stream.write_all(event.dump().as_bytes()).is_err()
                || stream.write_all(b"\n").is_err()
            {
                return Ok(());
            }
        }
        if stream.flush().is_err() {
            return Ok(());
        }
        if !follow || closed || core.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        slot.log.wait_until(Duration::from_millis(250), |s| {
            s.events.len() > offset || s.closed
        });
    }
}
