//! Minimal HTTP/1.1 substrate for the serve daemon.
//!
//! With no crates.io access there is no hyper/axum; the daemon speaks the
//! subset of HTTP/1.1 it needs over [`std::net::TcpStream`] directly:
//! request-line + headers + `Content-Length` bodies in, fixed responses or
//! `Connection: close` NDJSON streams out. Every connection serves exactly
//! one request (`Connection: close`), which keeps the state machine
//! trivial and lets streaming endpoints delimit their body by EOF.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use crate::util::Json;

/// Largest request body the daemon accepts (configs are a few KiB; this
/// bound stops a hostile `Content-Length` from ballooning memory).
const MAX_BODY: usize = 4 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    /// Uppercase HTTP method (`GET`, `POST`, ...).
    pub method: String,
    /// Decoded path without the query string, e.g. `/sessions/3/run`.
    pub path: String,
    /// Query parameters (`?from=4&follow=1`).
    pub query: BTreeMap<String, String>,
    /// Raw request body (at most `MAX_BODY` bytes).
    pub body: Vec<u8>,
}

impl Request {
    /// Path split into non-empty segments: `/sessions/3/run` ->
    /// `["sessions", "3", "run"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }

    /// A query parameter, parsed.
    pub fn query_opt<T: std::str::FromStr>(&self, key: &str) -> crate::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.query.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("query parameter '{key}'='{s}': {e}")),
        }
    }

    /// The body parsed as JSON; an empty body parses as an empty object so
    /// `POST /sessions/3/checkpoint` needs no payload.
    pub fn json_body(&self) -> crate::Result<Json> {
        if self.body.is_empty() {
            return Ok(Json::obj());
        }
        let text = std::str::from_utf8(&self.body)
            .map_err(|e| anyhow::anyhow!("request body is not UTF-8: {e}"))?;
        Json::parse(text).map_err(|e| anyhow::anyhow!("request body is not valid JSON: {e}"))
    }
}

/// Read and parse one request from the stream.
pub fn read_request(stream: &mut TcpStream) -> crate::Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_uppercase();
    let target = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line has no target"))?;
    let (path, query_str) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for pair in query_str.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(percent_decode(k), percent_decode(v));
    }
    let path = percent_decode(path);

    // Headers: we only need Content-Length.
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header)?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad Content-Length: {e}"))?;
            }
        }
    }
    anyhow::ensure!(content_length <= MAX_BODY, "request body too large ({content_length} bytes)");
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, query, body })
}

fn percent_decode(s: &str) -> String {
    fn hex(b: u8) -> Option<u8> {
        match b {
            b'0'..=b'9' => Some(b - b'0'),
            b'a'..=b'f' => Some(b - b'a' + 10),
            b'A'..=b'F' => Some(b - b'A' + 10),
            _ => None,
        }
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' if i + 2 < bytes.len() => match (hex(bytes[i + 1]), hex(bytes[i + 2])) {
                (Some(hi), Some(lo)) => {
                    out.push(hi << 4 | lo);
                    i += 3;
                }
                _ => {
                    out.push(b'%');
                    i += 1;
                }
            },
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete response with a body and close-delimited semantics.
pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> crate::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Write a JSON response.
pub fn respond_json(stream: &mut TcpStream, status: u16, json: &Json) -> crate::Result<()> {
    respond(stream, status, "application/json", json.dump().as_bytes())
}

/// Write a JSON error body `{"error": message}`.
pub fn respond_error(stream: &mut TcpStream, status: u16, message: &str) -> crate::Result<()> {
    let mut j = Json::obj();
    j.set("error", Json::Str(message.to_string()));
    respond_json(stream, status, &j)
}

/// Start an EOF-delimited streaming response (NDJSON): writes the header
/// block; the caller then writes newline-terminated JSON lines directly and
/// simply drops the stream to finish.
pub fn start_stream(stream: &mut TcpStream, content_type: &str) -> crate::Result<()> {
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may unwrap; the deny covers the daemon
mod tests {
    use super::*;

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c"), "a b c");
        assert_eq!(percent_decode("plain"), "plain");
        assert_eq!(percent_decode("%zz"), "%zz");
        assert_eq!(percent_decode("trailing%2"), "trailing%2");
    }

    #[test]
    fn request_over_a_socket_roundtrips() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"POST /sessions/3/run?from=4&follow=1 HTTP/1.1\r\n\
                  Host: x\r\nContent-Length: 13\r\n\r\n{\"rounds\": 2}",
            )
            .unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        });
        let (mut conn, _) = listener.accept().unwrap();
        let req = read_request(&mut conn).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.segments(), vec!["sessions", "3", "run"]);
        assert_eq!(req.query.get("from").map(String::as_str), Some("4"));
        assert_eq!(req.query_opt::<usize>("follow").unwrap(), Some(1));
        assert_eq!(
            req.json_body().unwrap().get("rounds").unwrap().as_usize().unwrap(),
            2
        );
        let mut j = Json::obj();
        j.set("ok", Json::Bool(true));
        respond_json(&mut conn, 200, &j).unwrap();
        drop(conn);
        let reply = client.join().unwrap();
        assert!(reply.starts_with("HTTP/1.1 200 OK"), "{reply}");
        assert!(reply.ends_with("{\"ok\":true}"), "{reply}");
    }

    #[test]
    fn empty_body_parses_as_empty_object() {
        let r = Request {
            method: "POST".into(),
            path: "/x".into(),
            query: BTreeMap::new(),
            body: vec![],
        };
        assert_eq!(r.json_body().unwrap(), Json::obj());
    }
}
