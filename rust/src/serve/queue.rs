//! The daemon's internal plumbing: a broadcastable per-session event log
//! and the bounded worker-pool job queue.
//!
//! Shapes follow the exemplars named in ROADMAP.md: commands flow to each
//! session over its own mpsc channel (the [`SessionDriver`] command
//! sender); everything a session does is appended to an [`EventLog`] that
//! any number of HTTP readers can tail by offset (Condvar broadcast), so
//! `GET /sessions/:id/events?follow=1` is a plain log-follower and never
//! perturbs training.
//!
//! [`SessionDriver`]: crate::experiment::SessionDriver

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::lock;

use crate::experiment::SessionEvent;
use crate::metrics::Record;
use crate::util::Json;

/// Live, mutexed view of one session, fed exclusively by its event sink.
#[derive(Default)]
pub struct LogState {
    /// Every event as its NDJSON line payload, append-only.
    pub events: Vec<Json>,
    /// Per-round reports as JSON, append-only (offset = round order).
    pub reports: Vec<Json>,
    /// History records mirrored from round events (seeded from the
    /// restored history on adopted sessions) — rendered by
    /// `/sessions/:id/history.csv` byte-identically to
    /// [`crate::metrics::History::write_csv`].
    pub records: Vec<Record>,
    /// Checkpoint files announced so far (periodic and on-demand).
    pub checkpoints: Vec<PathBuf>,
    /// Rounds completed.
    pub round: usize,
    /// Round budget exhausted (or an observer asked to stop).
    pub done: bool,
    /// The session finished and its engine shut down; terminal.
    pub closed: bool,
    /// Most recent command/step error, if any.
    pub last_error: Option<String>,
}

/// Append-only event log with Condvar broadcast, one per session.
#[derive(Default)]
pub struct EventLog {
    state: Mutex<LogState>,
    cond: Condvar,
}

/// A [`SessionEvent`] as its wire (NDJSON line) payload.
pub fn event_json(event: &SessionEvent) -> Json {
    let mut j = Json::obj();
    match event {
        SessionEvent::Round(report) => {
            j.set("type", Json::Str("round".into()))
                .set("report", report.to_json());
        }
        SessionEvent::Checkpointed { round, path } => {
            j.set("type", Json::Str("checkpointed".into()))
                .set("round", Json::Num(*round as f64))
                .set("path", Json::Str(path.display().to_string()));
        }
        SessionEvent::Idle { round, done } => {
            j.set("type", Json::Str("idle".into()))
                .set("round", Json::Num(*round as f64))
                .set("done", Json::Bool(*done));
        }
        SessionEvent::Error { round, message } => {
            j.set("type", Json::Str("error".into()))
                .set("round", Json::Num(*round as f64))
                .set("message", Json::Str(message.clone()));
        }
        SessionEvent::Closed { round } => {
            j.set("type", Json::Str("closed".into()))
                .set("round", Json::Num(*round as f64));
        }
    }
    j
}

impl EventLog {
    /// Run `f` with the locked state (the one mutation/read entry point).
    pub fn with<R>(&self, f: impl FnOnce(&mut LogState) -> R) -> R {
        let mut state = lock(&self.state);
        f(&mut state)
    }

    /// Absorb one session event: append its wire form, update the live
    /// mirrors, wake every waiter.
    pub fn absorb(&self, event: &SessionEvent) {
        let line = event_json(event);
        let mut state = lock(&self.state);
        match event {
            SessionEvent::Round(report) => {
                state.round = report.round;
                state.records.push(Record {
                    round: report.round,
                    sim_time: report.sim_time,
                    loss: report.outcome.mean_loss,
                    test_acc: report.test_acc,
                });
                state.reports.push(report.to_json());
            }
            SessionEvent::Checkpointed { path, .. } => {
                // On-demand rewrites of a round already checkpointed
                // replace in place (same path), keeping the list a set.
                if !state.checkpoints.contains(path) {
                    state.checkpoints.push(path.clone());
                }
            }
            SessionEvent::Idle { round, done } => {
                state.round = *round;
                state.done = *done;
            }
            SessionEvent::Error { message, .. } => {
                state.last_error = Some(message.clone());
            }
            SessionEvent::Closed { round } => {
                state.round = *round;
                state.closed = true;
            }
        }
        state.events.push(line);
        drop(state);
        self.cond.notify_all();
    }

    /// Wake all waiters without a new event (daemon shutdown: followers
    /// must re-check their exit conditions).
    pub fn nudge(&self) {
        self.cond.notify_all();
    }

    /// Block until `pred` holds or `timeout` elapses; returns whether the
    /// predicate held.
    pub fn wait_until(&self, timeout: Duration, pred: impl Fn(&LogState) -> bool) -> bool {
        let deadline = Instant::now() + timeout;
        let mut state = lock(&self.state);
        loop {
            if pred(&state) {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (next, _) = self
                .cond
                .wait_timeout(state, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            state = next;
        }
    }

    /// Events from `offset` on (a follower's catch-up read), plus whether
    /// the session is closed.
    pub fn events_from(&self, offset: usize) -> (Vec<Json>, bool) {
        let state = lock(&self.state);
        let tail = state.events.get(offset..).unwrap_or(&[]).to_vec();
        (tail, state.closed)
    }
}

/// Job id a worker interprets as "exit now" (daemon shutdown).
pub const STOP: u64 = u64::MAX;

/// The worker pool's shared job queue: session ids, multiple producers
/// (HTTP handlers, re-kicks), multiple consumers (the workers, sharing the
/// receiver behind a mutex).
pub struct JobQueue {
    tx: Sender<u64>,
    rx: Mutex<Receiver<u64>>,
    /// Jobs pushed but not yet claimed — the backpressure signal
    /// ([`JobQueue::depth`]); stop sentinels don't count.
    depth: AtomicUsize,
}

impl JobQueue {
    /// Empty queue.
    pub fn new() -> JobQueue {
        let (tx, rx) = std::sync::mpsc::channel();
        JobQueue { tx, rx: Mutex::new(rx), depth: AtomicUsize::new(0) }
    }

    /// Enqueue a session for pumping. Duplicates are harmless: a worker
    /// that finds the session already taken simply drops the job.
    pub fn push(&self, id: u64) {
        if self.tx.send(id).is_ok() {
            self.depth.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Ask one worker to exit. Bypasses the depth accounting: shutdown
    /// must never be subject to backpressure.
    pub fn push_stop(&self) {
        let _ = self.tx.send(STOP);
    }

    /// Blocking pop; `None` means exit (stop sentinel or queue torn down).
    pub fn pop(&self) -> Option<u64> {
        let id = lock(&self.rx).recv().ok()?;
        if id == STOP {
            return None;
        }
        self.depth.fetch_sub(1, Ordering::SeqCst);
        Some(id)
    }

    /// Jobs enqueued but not yet claimed by a worker.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may unwrap; the deny covers the daemon
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn absorb_updates_mirrors_and_wakes_waiters() {
        let log = Arc::new(EventLog::default());
        let waiter = {
            let log = log.clone();
            std::thread::spawn(move || {
                log.wait_until(Duration::from_secs(10), |s| s.closed)
            })
        };
        log.absorb(&SessionEvent::Error { round: 3, message: "boom".into() });
        log.absorb(&SessionEvent::Closed { round: 3 });
        assert!(waiter.join().unwrap());
        log.with(|s| {
            assert_eq!(s.round, 3);
            assert!(s.closed);
            assert_eq!(s.last_error.as_deref(), Some("boom"));
            assert_eq!(s.events.len(), 2);
        });
        let (tail, closed) = log.events_from(1);
        assert_eq!(tail.len(), 1);
        assert!(closed);
        assert_eq!(tail[0].get("type").unwrap().as_str().unwrap(), "closed");
    }

    #[test]
    fn wait_until_times_out() {
        let log = EventLog::default();
        assert!(!log.wait_until(Duration::from_millis(20), |s| s.round > 0));
    }

    #[test]
    fn job_queue_delivers_in_order_and_stops() {
        let q = JobQueue::new();
        q.push(1);
        q.push(2);
        q.push_stop();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn job_queue_depth_tracks_unclaimed_jobs_not_stops() {
        let q = JobQueue::new();
        assert_eq!(q.depth(), 0);
        q.push(1);
        q.push(2);
        q.push_stop();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.depth(), 1);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.depth(), 0);
        assert_eq!(q.pop(), None);
        assert_eq!(q.depth(), 0);
    }
}
