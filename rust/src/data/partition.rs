//! IID and non-IID data partitioning across edge devices.
//!
//! Non-IID follows the paper exactly: "the dataset is first sorted based on
//! class labels, and then partitioned into 40 shards, with each of 20 edge
//! devices receiving two randomly distributed shards" — generalised to
//! `2 * n_devices` shards / 2 shards per device.

use super::Dataset;
use crate::config::Partition;
use crate::rng::Pcg32;

/// IID: shuffle indices and deal them round-robin.
pub fn split_iid(dataset: &Dataset, n_devices: usize, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..dataset.len()).collect();
    rng.shuffle(&mut idx);
    let mut parts = vec![Vec::with_capacity(dataset.len() / n_devices + 1); n_devices];
    for (k, i) in idx.into_iter().enumerate() {
        parts[k % n_devices].push(i);
    }
    parts
}

/// Paper non-IID: sort by label, cut into `2 * n_devices` shards, deal 2
/// random shards to each device.
pub fn shards_non_iid(dataset: &Dataset, n_devices: usize, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    let n_shards = 2 * n_devices;
    let mut idx: Vec<usize> = (0..dataset.len()).collect();
    idx.sort_by_key(|&i| dataset.labels[i]);

    let shard_len = idx.len() / n_shards;
    let mut shard_order: Vec<usize> = (0..n_shards).collect();
    rng.shuffle(&mut shard_order);

    let mut parts = vec![Vec::with_capacity(2 * shard_len); n_devices];
    for (slot, &shard) in shard_order.iter().enumerate() {
        let dev = slot / 2;
        let lo = shard * shard_len;
        let hi = if shard == n_shards - 1 { idx.len() } else { lo + shard_len };
        parts[dev].extend_from_slice(&idx[lo..hi]);
    }
    parts
}

/// Dispatch on the configured partition scheme.
pub fn partition(
    dataset: &Dataset,
    scheme: Partition,
    n_devices: usize,
    rng: &mut Pcg32,
) -> Vec<Vec<usize>> {
    match scheme {
        Partition::Iid => split_iid(dataset, n_devices, rng),
        Partition::NonIidShards => shards_non_iid(dataset, n_devices, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn label_diversity(d: &Dataset, part: &[usize]) -> usize {
        let mut seen = std::collections::HashSet::new();
        for &i in part {
            seen.insert(d.labels[i]);
        }
        seen.len()
    }

    #[test]
    fn iid_covers_all_samples_disjointly() {
        let d = Dataset::synthetic(200, 10, 1);
        let mut rng = Pcg32::seeded(2);
        let parts = split_iid(&d, 4, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 200);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 200);
    }

    #[test]
    fn iid_parts_are_label_diverse() {
        let d = Dataset::synthetic(400, 10, 3);
        let mut rng = Pcg32::seeded(4);
        let parts = split_iid(&d, 4, &mut rng);
        for p in &parts {
            assert_eq!(label_diversity(&d, p), 10);
        }
    }

    #[test]
    fn non_iid_parts_are_label_skewed() {
        let d = Dataset::synthetic(2000, 10, 5);
        let mut rng = Pcg32::seeded(6);
        let parts = shards_non_iid(&d, 20, &mut rng);
        assert_eq!(parts.len(), 20);
        // Two shards of a label-sorted set touch at most ~4 labels
        // (usually 2); definitely far fewer than 10.
        for p in &parts {
            assert!(label_diversity(&d, p) <= 4, "{}", label_diversity(&d, p));
        }
    }

    #[test]
    fn non_iid_covers_nearly_all_samples() {
        let d = Dataset::synthetic(2000, 10, 7);
        let mut rng = Pcg32::seeded(8);
        let parts = shards_non_iid(&d, 20, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 2000); // 2000 divides evenly into 40 shards
    }

    #[test]
    fn identical_seeds_give_identical_partitions() {
        // Partition determinism underpins run reproducibility (and the
        // scenario suite's bit-identical round histories): same seed, same
        // dataset => the exact same index assignment, run after run.
        let d = Dataset::synthetic(1000, 10, 11);
        for seed in [0u64, 1, 42, u64::MAX] {
            let a = split_iid(&d, 7, &mut Pcg32::seeded(seed));
            let b = split_iid(&d, 7, &mut Pcg32::seeded(seed));
            assert_eq!(a, b, "split_iid diverged for seed {seed}");

            let a = shards_non_iid(&d, 7, &mut Pcg32::seeded(seed));
            let b = shards_non_iid(&d, 7, &mut Pcg32::seeded(seed));
            assert_eq!(a, b, "shards_non_iid diverged for seed {seed}");
        }
        // And different seeds actually differ.
        let a = split_iid(&d, 7, &mut Pcg32::seeded(1));
        let b = split_iid(&d, 7, &mut Pcg32::seeded(2));
        assert_ne!(a, b);
    }

    #[test]
    fn shards_lose_no_samples_on_remainder() {
        // 2003 % 14 shards != 0: the trailing remainder must land in the
        // last shard, not fall off the end.
        for (len, n_devices) in [(2003usize, 7usize), (101, 4), (999, 10)] {
            let d = Dataset::synthetic(len, 10, 13);
            let mut rng = Pcg32::seeded(17);
            let parts = shards_non_iid(&d, n_devices, &mut rng);
            assert_eq!(parts.len(), n_devices);
            let mut all: Vec<usize> = parts.concat();
            assert_eq!(all.len(), len, "len {len} across {n_devices} devices");
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), len, "duplicated samples for len {len}");
        }
    }

    #[test]
    fn partition_dispatch() {
        let d = Dataset::synthetic(100, 10, 9);
        let mut rng = Pcg32::seeded(10);
        let iid = partition(&d, Partition::Iid, 5, &mut rng);
        let mut rng = Pcg32::seeded(10);
        let nid = partition(&d, Partition::NonIidShards, 5, &mut rng);
        assert_eq!(iid.len(), 5);
        assert_eq!(nid.len(), 5);
    }
}
