//! Mini-batch sampling from a device's local partition (step a1 of the
//! split-training stage: "each edge device i randomly samples a mini-batch
//! B_i^t ⊆ D_i containing b_i data samples").

use super::{Dataset, PIXELS};
use crate::rng::Pcg32;

/// Per-device batch sampler with its own deterministic stream.
#[derive(Debug, Clone)]
pub struct BatchSampler {
    indices: Vec<usize>,
    rng: Pcg32,
}

/// A host mini-batch ready for the runtime: images `[b,32,32,3]`, one-hot
/// labels `[b,C]`, per-row weights `[b]` (1/0 after bucket padding).
#[derive(Debug, Clone)]
pub struct HostBatch {
    /// Images, row-major `[b, 32, 32, 3]` (zero rows beyond `true_batch`).
    pub x: Vec<f32>,
    /// One-hot labels `[b, C]`.
    pub onehot: Vec<f32>,
    /// Per-row loss weights `[b]`: 1 for real rows, 0 for padding.
    pub weights: Vec<f32>,
    /// True (unpadded) batch size.
    pub true_batch: u32,
    /// Padded (bucket) batch size — the artifact's shape.
    pub padded_batch: u32,
}

impl BatchSampler {
    /// Sampler over a device's partition `indices` with its own RNG stream.
    pub fn new(indices: Vec<usize>, rng: Pcg32) -> BatchSampler {
        assert!(!indices.is_empty(), "device has an empty partition");
        BatchSampler { indices, rng }
    }

    /// Size of the device's data partition.
    pub fn partition_len(&self) -> usize {
        self.indices.len()
    }

    /// Raw RNG state `(state, inc)` for checkpointing (the partition
    /// indices are deterministic from the config and are rebuilt on
    /// resume; only the stream cursor evolves).
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state_parts()
    }

    /// Restore the sampler's RNG stream from checkpointed state.
    pub fn restore_rng(&mut self, state: u64, inc: u64) {
        self.rng = Pcg32::from_state_parts(state, inc);
    }

    /// Sample a batch of `b` samples (with replacement when b exceeds the
    /// partition) and pad it to `bucket` rows with zero-weighted rows.
    pub fn sample(&mut self, dataset: &Dataset, b: u32, bucket: u32) -> HostBatch {
        assert!(bucket >= b, "bucket {bucket} < batch {b}");
        let c = dataset.n_classes;
        let (bu, bb) = (b as usize, bucket as usize);
        let mut x = vec![0.0f32; bb * PIXELS];
        let mut onehot = vec![0.0f32; bb * c];
        let mut weights = vec![0.0f32; bb];

        let picks: Vec<usize> = if bu <= self.indices.len() {
            self.rng
                .sample_indices(self.indices.len(), bu)
                .into_iter()
                .map(|k| self.indices[k])
                .collect()
        } else {
            (0..bu)
                .map(|_| self.indices[self.rng.below(self.indices.len() as u32) as usize])
                .collect()
        };

        for (row, &i) in picks.iter().enumerate() {
            x[row * PIXELS..(row + 1) * PIXELS].copy_from_slice(dataset.image(i));
            onehot[row * c + dataset.labels[i] as usize] = 1.0;
            weights[row] = 1.0;
        }
        // Padded rows keep weight 0 but need a valid one-hot so argmax
        // comparisons in the artifact are well-defined.
        for row in bu..bb {
            onehot[row * c] = 1.0;
        }

        HostBatch { x, onehot, weights, true_batch: b, padded_batch: bucket }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Dataset, BatchSampler) {
        let d = Dataset::synthetic(64, 10, 11);
        let s = BatchSampler::new((0..64).collect(), Pcg32::seeded(12));
        (d, s)
    }

    #[test]
    fn batch_shapes_match_bucket() {
        let (d, mut s) = setup();
        let b = s.sample(&d, 5, 8);
        assert_eq!(b.x.len(), 8 * PIXELS);
        assert_eq!(b.onehot.len(), 8 * 10);
        assert_eq!(b.weights.len(), 8);
    }

    #[test]
    fn weights_mark_real_rows() {
        let (d, mut s) = setup();
        let b = s.sample(&d, 5, 8);
        assert_eq!(b.weights[..5], [1.0; 5]);
        assert_eq!(b.weights[5..], [0.0; 3]);
    }

    #[test]
    fn every_row_has_valid_onehot() {
        let (d, mut s) = setup();
        let b = s.sample(&d, 3, 4);
        for row in 0..4 {
            let sum: f32 = b.onehot[row * 10..(row + 1) * 10].iter().sum();
            assert_eq!(sum, 1.0, "row {row}");
        }
    }

    #[test]
    fn sampling_without_replacement_within_partition() {
        let (d, mut s) = setup();
        let b = s.sample(&d, 64, 64);
        // all 64 distinct images used
        let mut rows: Vec<&[f32]> = (0..64).map(|r| &b.x[r * PIXELS..r * PIXELS + 8]).collect();
        rows.sort_by(|a, z| a.partial_cmp(z).unwrap());
        rows.dedup();
        assert_eq!(rows.len(), 64);
    }

    #[test]
    fn oversampling_with_replacement_when_batch_exceeds_partition() {
        let d = Dataset::synthetic(4, 2, 13);
        let mut s = BatchSampler::new((0..4).collect(), Pcg32::seeded(14));
        let b = s.sample(&d, 8, 8);
        assert_eq!(b.true_batch, 8);
        assert_eq!(b.weights, vec![1.0; 8]);
    }

    #[test]
    fn rng_state_roundtrip_resumes_the_batch_stream() {
        let (d, mut s) = setup();
        s.sample(&d, 8, 8);
        let (state, inc) = s.rng_state();
        let mut resumed = BatchSampler::new((0..64).collect(), Pcg32::seeded(999));
        resumed.restore_rng(state, inc);
        let a = s.sample(&d, 8, 8);
        let b = resumed.sample(&d, 8, 8);
        assert_eq!(a.x, b.x);
        assert_eq!(a.onehot, b.onehot);
    }

    #[test]
    fn deterministic_per_seed() {
        let d = Dataset::synthetic(64, 10, 15);
        let mut s1 = BatchSampler::new((0..64).collect(), Pcg32::seeded(16));
        let mut s2 = BatchSampler::new((0..64).collect(), Pcg32::seeded(16));
        let b1 = s1.sample(&d, 8, 8);
        let b2 = s2.sample(&d, 8, 8);
        assert_eq!(b1.x, b2.x);
    }
}
