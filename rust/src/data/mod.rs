//! Data substrate: deterministic synthetic CIFAR-like dataset plus the
//! paper's IID and shard-based non-IID partitioners.
//!
//! Substitution note (DESIGN.md §4): real CIFAR-10/100 is not available in
//! this environment. The generator produces class-conditional images —
//! a per-class latent anchor pushed through a fixed random projection to
//! 32x32x3 with additive latent noise — which preserves exactly the
//! properties the paper's phenomena depend on: learnable class structure,
//! batch-size-dependent gradient variance, and label-skewed non-IID shards.

mod partition;
mod sampler;

pub use partition::{partition, shards_non_iid, split_iid};
pub use sampler::BatchSampler;

use crate::rng::Pcg32;

/// Image side length (CIFAR-shaped 32x32 inputs).
pub const IMG: usize = 32;
/// Input channels (RGB).
pub const CH: usize = 3;
/// Floats per image (`IMG * IMG * CH`).
pub const PIXELS: usize = IMG * IMG * CH;
const LATENT: usize = 64;

/// A dataset of images (row-major `[n, 32, 32, 3]`) with integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major `[n, 32, 32, 3]` pixel data.
    pub images: Vec<f32>,
    /// Integer class labels, one per image.
    pub labels: Vec<u16>,
    /// Number of distinct classes.
    pub n_classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset has no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Pixel slice of sample `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * PIXELS..(i + 1) * PIXELS]
    }

    /// Generate `n` samples with `n_classes` classes, deterministically.
    ///
    /// Latent model: x = tanh(W (z_c + noise_scale * eps)) where z_c is the
    /// class anchor and W a fixed Gaussian projection — separable but not
    /// trivially so (noise_scale 0.45 gives ~80-95% achievable accuracy for
    /// a small CNN, mirroring CIFAR-10 difficulty ordering).
    pub fn synthetic(n: usize, n_classes: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::new(seed, 0xDA7A);
        // Fixed projection W: LATENT -> PIXELS.
        let proj: Vec<f32> = (0..LATENT * PIXELS)
            .map(|_| (rng.normal() * (1.0 / (LATENT as f64).sqrt())) as f32)
            .collect();
        // Class anchors.
        let anchors: Vec<f32> = (0..n_classes * LATENT)
            .map(|_| rng.normal() as f32)
            .collect();

        let noise_scale = 0.45f32;
        let mut images = Vec::with_capacity(n * PIXELS);
        let mut labels = Vec::with_capacity(n);
        let mut z = vec![0.0f32; LATENT];
        for i in 0..n {
            let class = (i % n_classes) as u16;
            labels.push(class);
            let anchor = &anchors[class as usize * LATENT..(class as usize + 1) * LATENT];
            for (zk, &ak) in z.iter_mut().zip(anchor) {
                *zk = ak + noise_scale * rng.normal() as f32;
            }
            for p in 0..PIXELS {
                let mut acc = 0.0f32;
                for (k, &zk) in z.iter().enumerate() {
                    acc += proj[k * PIXELS + p] * zk;
                }
                images.push(acc.tanh());
            }
        }
        Dataset { images, labels, n_classes }
    }

    /// Standard train/test pair with disjoint noise streams.
    pub fn train_test(n_train: usize, n_test: usize, n_classes: usize, seed: u64) -> (Dataset, Dataset) {
        // Same anchors/projection (same seed), different sample indices:
        // generate jointly then split so the test set is in-distribution.
        let all = Dataset::synthetic(n_train + n_test, n_classes, seed);
        let train = Dataset {
            images: all.images[..n_train * PIXELS].to_vec(),
            labels: all.labels[..n_train].to_vec(),
            n_classes,
        };
        let test = Dataset {
            images: all.images[n_train * PIXELS..].to_vec(),
            labels: all.labels[n_train..].to_vec(),
            n_classes,
        };
        (train, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic() {
        let a = Dataset::synthetic(64, 10, 7);
        let b = Dataset::synthetic(64, 10, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let a = Dataset::synthetic(16, 10, 1);
        let b = Dataset::synthetic(16, 10, 2);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn labels_are_balanced() {
        let d = Dataset::synthetic(1000, 10, 3);
        let mut counts = vec![0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn pixels_bounded_by_tanh() {
        let d = Dataset::synthetic(32, 10, 4);
        assert!(d.images.iter().all(|&p| (-1.0..=1.0).contains(&p)));
    }

    #[test]
    fn classes_are_separated() {
        // Same-class samples must be closer (on average) than cross-class:
        // otherwise nothing is learnable and every accuracy figure is noise.
        let d = Dataset::synthetic(200, 10, 5);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for i in 0..50 {
            for j in (i + 1)..50 {
                let dd = dist(d.image(i), d.image(j));
                if d.labels[i] == d.labels[j] {
                    same = (same.0 + dd, same.1 + 1);
                } else {
                    diff = (diff.0 + dd, diff.1 + 1);
                }
            }
        }
        let same_avg = same.0 / same.1 as f32;
        let diff_avg = diff.0 / diff.1 as f32;
        assert!(same_avg < diff_avg * 0.8, "same {same_avg} diff {diff_avg}");
    }

    #[test]
    fn train_test_split_sizes() {
        let (tr, te) = Dataset::train_test(100, 40, 10, 6);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 40);
    }
}
