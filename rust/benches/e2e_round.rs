//! End-to-end round throughput: a full split-training step (all devices,
//! steps a1–a5 + post-round aggregation) in sequential, single-engine
//! concurrent, and pooled-concurrent modes, plus evaluation cost. The
//! headline L3 number for DESIGN.md §8.
//!
//! Emits a machine-readable `BENCH_e2e.json` at the repo root (override
//! with `HASFL_BENCH_JSON=path`; smoke mode writes to the temp dir) so
//! future PRs have a perf trajectory to regress against.

#[path = "common/mod.rs"]
mod common;

use hasfl::config::StrategyKind;
use hasfl::experiment::{Experiment, Preset, Session};
use hasfl::runtime::EngineStats;
use hasfl::util::Json;

const FLEET: usize = 4;
const BATCH: u32 = 16;
const CUT: usize = 4;

fn build_session(dir: &std::path::Path, pool: usize) -> Session {
    Experiment::builder()
        .preset(Preset::Small)
        .devices(FLEET)
        .strategy(StrategyKind::Fixed)
        .fixed_batch(BATCH)
        .fixed_cut(CUT)
        // Big round budget, no scheduled evals, no aggregation windows:
        // step() timing stays pure per-round work.
        .rounds(1_000_000)
        .eval_every(1_000_000)
        .agg_interval(1_000_000)
        .engine_pool(pool)
        .tune(|c| {
            c.train.train_samples = 1024;
            c.train.test_samples = 256;
        })
        .artifacts(dir)
        .build()
        .expect("session")
}

/// Per-round marshal accounting for one session's engine stats.
fn marshal_json(stats: &EngineStats, rounds: usize) -> Json {
    let rounds = rounds.max(1) as f64;
    let packed = stats.upload_bytes as f64;
    let saved = stats.buffer_hit_bytes as f64;
    let mut j = Json::obj();
    j.set("engine_pool_width", Json::Num(stats.pool_width as f64))
        .set("rounds", Json::Num(rounds))
        .set("exec_secs", Json::Num(stats.exec_secs))
        .set("upload_secs", Json::Num(stats.upload_secs))
        .set("download_secs", Json::Num(stats.download_secs))
        .set("marshal_secs", Json::Num(stats.marshal_secs()))
        .set("upload_bytes_per_round", Json::Num(packed / rounds))
        .set("download_bytes_per_round", Json::Num(stats.download_bytes as f64 / rounds))
        .set("buffer_hit_bytes_per_round", Json::Num(saved / rounds))
        // Fraction of would-be upload bytes that skipped literal packing
        // thanks to the buffer cache (the seed packed everything).
        .set("upload_saved_frac", Json::Num(saved / (saved + packed).max(1.0)))
        .set("buffer_hits", Json::Num(stats.buffer_hits as f64))
        .set("buffer_misses", Json::Num(stats.buffer_misses as f64));
    j
}

/// Kernel-level native series: the conv3 GEMM triple (`mm` forward,
/// `mm_at_b` weight grad, `mm_a_bt` input grad) at batch-16 shapes,
/// naive reference vs the blocked/tiled kernels of DESIGN.md §14. Pure
/// Rust with no engine, so this series flows from every runner — even
/// PJRT-backed ones — and `ci.sh` gates on its `speedup_p50`.
fn kernel_series() -> Json {
    use hasfl::backend::ops;
    // conv3 at batch 16: m = 16·16·16 patch rows, k = 9·16 taps, n = 32 filters.
    const M: usize = 16 * 16 * 16;
    const K: usize = 144;
    const N: usize = 32;
    let mut rng = hasfl::rng::Pcg32::seeded(14);
    let a: Vec<f32> = (0..M * K).map(|_| rng.normal() as f32 * 0.1).collect();
    let w: Vec<f32> = (0..K * N).map(|_| rng.normal() as f32 * 0.1).collect();
    let dz: Vec<f32> = (0..M * N).map(|_| rng.normal() as f32 * 0.1).collect();
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    // Enough samples for a stable p50 even in smoke mode: the CI perf
    // gate reads this series, and a single smoke sample would flake.
    let (wu, it) = if common::smoke() { (1, 5) } else { (3, 20) };
    let r_naive = common::bench_raw("kernel_gemm_naive_conv3_b16", wu, it, || {
        std::hint::black_box(ops::mm_ref(&a, &w, M, K, N));
        std::hint::black_box(ops::mm_at_b_ref(&a, &dz, M, K, N));
        std::hint::black_box(ops::mm_a_bt_ref(&dz, &w, M, N, K));
    });
    let r_tiled = common::bench_raw("kernel_gemm_tiled_conv3_b16", wu, it, || {
        std::hint::black_box(ops::mm(&a, &w, M, K, N, threads));
        std::hint::black_box(ops::mm_at_b(&a, &dz, M, K, N, threads));
        std::hint::black_box(ops::mm_a_bt(&dz, &w, M, N, K, threads));
    });

    let mut j = Json::obj();
    j.set("m", Json::Num(M as f64))
        .set("k", Json::Num(K as f64))
        .set("n", Json::Num(N as f64))
        .set("threads", Json::Num(threads as f64))
        .set("naive", r_naive.to_json_ms())
        .set("tiled", r_tiled.to_json_ms())
        .set("speedup_p50", Json::Num(r_naive.summary.p50 / r_tiled.summary.p50));
    j
}

fn bench_json_path() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("HASFL_BENCH_JSON") {
        return p.into();
    }
    if common::smoke() {
        return std::env::temp_dir().join("BENCH_e2e.json");
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_e2e.json")
}

fn main() {
    let dir = common::artifacts_dir();
    println!("backend: {}", common::backend().as_str());

    // Kernel series first: pure CPU, no engine or session state to perturb.
    let kernels = kernel_series();

    // Sequential baseline (single lane, the seed data path).
    let mut seq = build_session(&dir, 1);
    let r_seq = common::bench("step_sequential_n4_b16", 2, 15, || {
        std::hint::black_box(seq.step().unwrap());
    });
    let seq_stats = seq.engine_stats().unwrap();
    let seq_rounds = seq.round();
    seq.finish().unwrap();

    // Concurrent actors over one engine lane: message passing overlaps,
    // compute still serializes.
    let mut conc1 = build_session(&dir, 1);
    conc1.set_concurrent(true);
    let r_conc1 = common::bench("step_concurrent_pool1_n4_b16", 2, 15, || {
        std::hint::black_box(conc1.step().unwrap());
    });
    conc1.finish().unwrap();

    // Pooled concurrent: devices spread over engine lanes (auto width).
    let mut pooled = build_session(&dir, 0);
    pooled.set_concurrent(true);
    let width = pooled.engine_width();
    let r_pool = common::bench(&format!("step_concurrent_pool{width}_n4_b16"), 2, 15, || {
        std::hint::black_box(pooled.step().unwrap());
    });
    let pool_stats = pooled.engine_stats().unwrap();
    let pool_rounds = pooled.round();

    let r_eval = common::bench("evaluate_testset_256", 1, 5, || {
        std::hint::black_box(pooled.evaluate_now().unwrap());
    });
    println!("engine (pooled): {}", pool_stats.summary());
    pooled.finish().unwrap();

    let mut j = Json::obj();
    j.set("bench", Json::Str("e2e_round".into()))
        .set("backend", Json::Str(common::backend().as_str().into()))
        .set("meta", common::meta_json(width))
        .set("smoke", Json::Bool(common::smoke()))
        .set("fleet", Json::Num(FLEET as f64))
        .set("fixed_batch", Json::Num(BATCH as f64))
        .set("fixed_cut", Json::Num(CUT as f64))
        .set("engine_pool_width", Json::Num(width as f64))
        .set("step_sequential", r_seq.to_json_ms())
        .set("step_concurrent_pool1", r_conc1.to_json_ms())
        .set("step_concurrent_pooled", r_pool.to_json_ms())
        .set("evaluate", r_eval.to_json_ms())
        .set("kernel_native", kernels)
        .set(
            "speedup_pool1_vs_sequential",
            Json::Num(r_seq.summary.p50 / r_conc1.summary.p50),
        )
        .set(
            "speedup_pooled_vs_sequential",
            Json::Num(r_seq.summary.p50 / r_pool.summary.p50),
        )
        .set("marshal_sequential", marshal_json(&seq_stats, seq_rounds))
        .set("marshal_pooled", marshal_json(&pool_stats, pool_rounds));

    let path = bench_json_path();
    std::fs::write(&path, j.dump()).expect("write bench json");
    println!("bench report -> {}", path.display());
}
