//! End-to-end round throughput: a full split-training step (all devices,
//! steps a1–a5 + post-round aggregation) in sequential vs concurrent-actor
//! mode, plus evaluation cost. The headline L3 number for DESIGN.md §8.

#[path = "common/mod.rs"]
mod common;

use hasfl::config::StrategyKind;
use hasfl::experiment::{Experiment, Preset};

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };

    let mut session = Experiment::builder()
        .preset(Preset::Small)
        .devices(4)
        .strategy(StrategyKind::Fixed)
        .fixed_batch(16)
        .fixed_cut(4)
        // Big round budget, no scheduled evals, no aggregation windows:
        // step() timing stays pure per-round work.
        .rounds(1_000_000)
        .eval_every(1_000_000)
        .agg_interval(1_000_000)
        .tune(|c| {
            c.train.train_samples = 1024;
            c.train.test_samples = 256;
        })
        .artifacts(&dir)
        .build()
        .expect("session");

    common::bench("step_sequential_n4_b16", 2, 15, || {
        std::hint::black_box(session.step().unwrap());
    });
    session.set_concurrent(true);
    common::bench("step_concurrent_n4_b16", 2, 15, || {
        std::hint::black_box(session.step().unwrap());
    });
    common::bench("evaluate_testset_256", 1, 5, || {
        std::hint::black_box(session.evaluate_now().unwrap());
    });

    let stats = session.engine_stats().unwrap();
    println!(
        "engine: {} execs, exec {:.2}s, marshal {:.2}s, {} compiles {:.1}s",
        stats.executions, stats.exec_secs, stats.marshal_secs, stats.compiles, stats.compile_secs
    );
    session.finish().unwrap();
}
