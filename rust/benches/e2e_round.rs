//! End-to-end round throughput: a full split-training round (all devices,
//! steps a1–a5 + aggregation) in sequential vs concurrent-actor mode, plus
//! evaluation cost. The headline L3 number for EXPERIMENTS.md §Perf.

#[path = "common/mod.rs"]
mod common;

use hasfl::config::{Config, StrategyKind};
use hasfl::coordinator::Trainer;

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };

    let mut cfg = Config::small();
    cfg.fleet.n_devices = 4;
    cfg.train.rounds = 1;
    cfg.strategy = StrategyKind::Fixed;
    cfg.fixed_batch = 16;
    cfg.fixed_cut = 4;
    cfg.train.train_samples = 1024;
    cfg.train.test_samples = 256;

    let mut trainer = Trainer::new(cfg.clone(), &dir).expect("trainer");
    common::bench("round_sequential_n4_b16", 2, 15, || {
        std::hint::black_box(trainer.run_round().unwrap());
    });
    common::bench("round_concurrent_n4_b16", 2, 15, || {
        std::hint::black_box(trainer.run_round_concurrent().unwrap());
    });
    common::bench("evaluate_testset_256", 1, 5, || {
        std::hint::black_box(trainer.evaluate().unwrap());
    });

    let stats = trainer.engine.stats_blocking().unwrap();
    println!(
        "engine: {} execs, exec {:.2}s, marshal {:.2}s, {} compiles {:.1}s",
        stats.executions, stats.exec_secs, stats.marshal_secs, stats.compiles, stats.compile_secs
    );
    trainer.engine.shutdown();
}
