//! Bench + table for Fig 2(b): per-round training latency versus batch
//! size at Table I scale (VGG-16, N=20, L_c = 8).
//!
//! Reports (i) the paper's fig2b rows (simulated latency per batch size)
//! and (ii) the wall-clock cost of evaluating the latency model itself —
//! it sits inside the optimizer's inner loop, so it must stay cheap.
//! Timings report min/p50/mean/p95; `HASFL_BENCH_SMOKE=1` runs one bare
//! iteration per case (the CI `make bench-smoke` path).

#[path = "common/mod.rs"]
mod common;

use hasfl::config::Config;
use hasfl::latency::{round_latency, Decisions};
use hasfl::model::ModelProfile;

fn main() {
    let cfg = Config::table1();
    let profile = ModelProfile::vgg16();
    let devices = cfg.sample_fleet();

    println!("--- Fig 2(b): per-round latency vs batch size (VGG-16, N=20, cut=8) ---");
    println!("{:>6} {:>12} {:>12} {:>12}", "batch", "T_S (s)", "T_A (s)", "T_total/round");
    for b in [4u32, 8, 16, 32, 64] {
        let dec = Decisions::uniform(devices.len(), b, 8);
        let lat = round_latency(&profile, &devices, &cfg.server, &dec);
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>12.4}",
            b,
            lat.t_split,
            lat.t_agg,
            lat.t_split + lat.t_agg / cfg.train.agg_interval as f64
        );
    }

    println!("--- latency-model evaluation cost ---");
    for &n in &[5usize, 20, 100] {
        let mut c = Config::table1();
        c.fleet.n_devices = n;
        let devs = c.sample_fleet();
        let dec = Decisions::uniform(n, 16, 8);
        common::bench(&format!("round_latency_n{n}"), 100, 2000, || {
            std::hint::black_box(round_latency(&profile, &devs, &c.server, &dec));
        });
    }
}
