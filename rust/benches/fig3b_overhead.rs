//! Bench + table for Fig 3(b): computing and communication overhead of SFL
//! at different model split points (VGG-16, b=16).
//! Timings report min/p50/mean/p95; `HASFL_BENCH_SMOKE=1` runs one bare
//! iteration per case (the CI `make bench-smoke` path).

#[path = "common/mod.rs"]
mod common;

use hasfl::latency::{round_client_flops, round_comm_bytes};
use hasfl::model::ModelProfile;

fn main() {
    println!("--- Fig 3(b): overhead vs model split point (VGG-16, b=16) ---");
    println!(
        "{:>4} {:>18} {:>18} {:>14}",
        "cut", "client GFLOPs", "comm MB/round", "act KB/sample"
    );
    for profile in [ModelProfile::vgg16(), ModelProfile::resnet18()] {
        println!("model: {}", profile.name);
        for cut in 1..profile.n_layers() {
            println!(
                "{:>4} {:>18.3} {:>18.3} {:>14.1}",
                cut,
                round_client_flops(&profile, 16, cut) / 1e9,
                round_comm_bytes(&profile, 16, cut) / 1e6,
                profile.psi(cut) / 1024.0
            );
        }
    }

    // Profile-table construction cost (manifest parse happens once per
    // process; analytic profiles are built per figure sweep).
    common::bench("vgg16_profile_build", 10, 1000, || {
        std::hint::black_box(ModelProfile::vgg16());
    });
    common::bench("resnet18_profile_build", 10, 1000, || {
        std::hint::black_box(ModelProfile::resnet18());
    });
}
