//! Runtime hot path: executable-cache hit cost, input marshalling (fresh
//! vs buffer-cached parameters), and the three split-step executions at
//! several (cut, bucket) points, on the resolved backend. This is the L3
//! perf target: the engine boundary must not dominate the actual compute.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use hasfl::model::{Manifest, Params};
use hasfl::rng::Pcg32;
use hasfl::runtime::{tensor_to_shared, BufKey, ExecInput, HostTensor, StepArtifacts};

fn main() {
    // Native kernel microbenches (print-only; the JSON trajectory series
    // lives in e2e_round.rs): naive reference vs blocked/tiled GEMM at
    // two hot conv shapes, plus the row-parallel im2col at 1..N threads.
    {
        use hasfl::backend::ops;
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let mut krng = Pcg32::seeded(77);
        let shapes = [("conv1_b32", 32 * 32 * 32, 27, 16), ("conv3_b16", 16 * 16 * 16, 144, 32)];
        for &(name, m, k, n) in &shapes {
            let a: Vec<f32> = (0..m * k).map(|_| krng.normal() as f32 * 0.1).collect();
            let w: Vec<f32> = (0..k * n).map(|_| krng.normal() as f32 * 0.1).collect();
            common::bench(&format!("kernel_mm_naive_{name}"), 2, 15, || {
                std::hint::black_box(ops::mm_ref(&a, &w, m, k, n));
            });
            common::bench(&format!("kernel_mm_tiled_{name}"), 2, 15, || {
                std::hint::black_box(ops::mm(&a, &w, m, k, n, threads));
            });
        }
        let x: Vec<f32> = (0..16 * 32 * 32 * 16).map(|_| krng.normal() as f32).collect();
        common::bench("kernel_im2col3x3_b16_t1", 2, 15, || {
            std::hint::black_box(ops::im2col3x3(&x, 16, 32, 32, 16, 1));
        });
        common::bench(&format!("kernel_im2col3x3_b16_t{threads}"), 2, 15, || {
            std::hint::black_box(ops::im2col3x3(&x, 16, 32, 32, 16, threads));
        });
    }

    let (engine, manifest) = common::engine_setup();
    println!("backend: {}", engine.backend().as_str());
    let params = Params::init(&manifest, 1);
    let classes = manifest.num_classes;
    let mut rng = Pcg32::seeded(5);
    let px = 32 * 32 * 3;

    for &(cut, bucket) in &[(2usize, 8u32), (4, 16), (6, 32)] {
        let b = bucket as usize;
        let x = HostTensor {
            shape: vec![b, 32, 32, 3],
            data: (0..b * px).map(|_| rng.normal() as f32 * 0.5).collect(),
        };
        let mut onehot = vec![0.0f32; b * classes];
        for r in 0..b {
            onehot[r * classes + r % classes] = 1.0;
        }
        let y = HostTensor { shape: vec![b, classes], data: onehot };
        let w = HostTensor { shape: vec![b], data: vec![1.0; b] };
        let sa = StepArtifacts::resolve(&manifest, cut, bucket).unwrap();

        // client_fwd, params marshalled fresh on every call (seed path)
        let mut cf_in = vec![x.clone()];
        cf_in.extend(params.client_slice(cut).iter().map(hasfl::runtime::tensor_to_host));
        common::bench(&format!("client_fwd_c{cut}_b{bucket}"), 3, 30, || {
            std::hint::black_box(
                engine.execute_blocking(&sa.client_fwd, cf_in.clone()).unwrap(),
            );
        });

        // client_fwd again, params served from the engine buffer cache
        let x_shared = Arc::new(x.clone());
        let cached_in: Vec<ExecInput> = std::iter::once(ExecInput::cached(
            BufKey { set: cut as u64, slot: BufKey::SLOT_X },
            1,
            Arc::clone(&x_shared),
        ))
        .chain(params.client_slice(cut).iter().enumerate().map(|(s, t)| {
            ExecInput::cached(BufKey { set: cut as u64, slot: s as u32 }, 1, tensor_to_shared(t))
        }))
        .collect();
        common::bench(&format!("client_fwd_c{cut}_b{bucket}_cached"), 3, 30, || {
            std::hint::black_box(
                engine
                    .execute_inputs_blocking(0, &sa.client_fwd, cached_in.clone())
                    .unwrap(),
            );
        });

        // server_step
        let a = engine.execute_blocking(&sa.client_fwd, cf_in.clone()).unwrap().remove(0);
        let mut ss_in = vec![a.clone(), y.clone(), w.clone()];
        ss_in.extend(params.server_slice(cut).iter().map(hasfl::runtime::tensor_to_host));
        common::bench(&format!("server_step_c{cut}_b{bucket}"), 3, 30, || {
            std::hint::black_box(
                engine.execute_blocking(&sa.server_step, ss_in.clone()).unwrap(),
            );
        });

        // client_bwd
        let mut cb_in = vec![x.clone(), a.clone()];
        cb_in.extend(params.client_slice(cut).iter().map(hasfl::runtime::tensor_to_host));
        common::bench(&format!("client_bwd_c{cut}_b{bucket}"), 3, 30, || {
            std::hint::black_box(
                engine.execute_blocking(&sa.client_bwd, cb_in.clone()).unwrap(),
            );
        });
    }

    // Marshalling overhead proxy: tiny executable, large inputs.
    let name = Manifest::full_name("full_fwd", 64);
    let x = HostTensor {
        shape: vec![64, 32, 32, 3],
        data: (0..64 * px).map(|_| rng.normal() as f32 * 0.5).collect(),
    };
    let mut inputs = vec![x];
    inputs.extend(params.tensors.iter().map(hasfl::runtime::tensor_to_host));
    common::bench("full_fwd_b64 (eval path)", 3, 30, || {
        std::hint::black_box(engine.execute_blocking(&name, inputs.clone()).unwrap());
    });

    let stats = engine.stats_blocking().unwrap();
    println!(
        "engine stats: {} execs, exec {:.3}s, marshal {:.3}s ({:.1}% of exec; \
         up {:.3}s / down {:.3}s), {} buffer hits saved {:.1} MiB",
        stats.executions,
        stats.exec_secs,
        stats.marshal_secs(),
        100.0 * stats.marshal_secs() / stats.exec_secs.max(1e-9),
        stats.upload_secs,
        stats.download_secs,
        stats.buffer_hits,
        stats.buffer_hit_bytes as f64 / (1024.0 * 1024.0)
    );
    engine.shutdown();
}
