//! Optimizer benches: Algorithm 2 (joint BS+MS), the BS Newton–Jacobi
//! solver, and the MS BCD/Dinkelbach solvers across fleet sizes. The paper
//! re-optimizes every I rounds, so solve time must be negligible next to a
//! training round (~seconds at paper scale).
//! Timings report min/p50/mean/p95; `HASFL_BENCH_SMOKE=1` runs one bare
//! iteration per case (the CI `make bench-smoke` path).

#[path = "common/mod.rs"]
mod common;

use hasfl::config::Config;
use hasfl::convergence::BoundParams;
use hasfl::latency::Decisions;
use hasfl::optimizer::{bs::BsSubproblem, ms, solve_joint, OptContext};
use hasfl::model::ModelProfile;
use hasfl::rng::Pcg32;

fn main() {
    let profile = ModelProfile::vgg16();
    let bound = BoundParams::default_for(&profile, 5e-4);

    for &n in &[5usize, 10, 20, 40] {
        let mut cfg = Config::table1();
        cfg.fleet.n_devices = n;
        let devices = cfg.sample_fleet();
        let ctx = OptContext {
            profile: &profile,
            devices: &devices,
            server: &cfg.server,
            bound: &bound,
            interval: cfg.train.agg_interval,
            epsilon: cfg.train.epsilon,
            batch_cap: cfg.train.batch_cap,
        };

        let incumbent = Decisions::uniform(n, 16, 4);
        common::bench(&format!("bs_newton_jacobi_n{n}"), 3, 50, || {
            let sp = BsSubproblem::from_context(&ctx, &incumbent);
            std::hint::black_box(sp.solve());
        });

        let batch = vec![16u32; n];
        common::bench(&format!("ms_bcd_n{n}"), 1, 10, || {
            let mut rng = Pcg32::seeded(7);
            std::hint::black_box(ms::solve_bcd(&ctx, &batch, &mut rng, 4));
        });

        common::bench(&format!("ms_dinkelbach_n{n}"), 1, 10, || {
            let mut rng = Pcg32::seeded(7);
            std::hint::black_box(ms::solve_dinkelbach(&ctx, &batch, &mut rng));
        });

        common::bench(&format!("joint_alg2_n{n}"), 1, 5, || {
            let mut rng = Pcg32::seeded(7);
            std::hint::black_box(solve_joint(&ctx, &mut rng, 8, 1e-6));
        });
    }
}
