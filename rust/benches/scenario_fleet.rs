//! The standing scale benchmark: the `mega-fleet` scenario (>= 1000
//! simulated devices) through the analytic pipeline — fleet evolution,
//! membership-driven BS re-solves, and the O(N) latency model. Future perf
//! PRs regress against `BENCH_scenario.json` (override the path with
//! `HASFL_SCENARIO_BENCH_JSON`; smoke mode writes to the temp dir).
//!
//! In CI smoke mode (`HASFL_BENCH_SMOKE=1`, `make bench-smoke`) the
//! headline number is exactly one 5-round mega-fleet run — the acceptance
//! smoke for the scenario engine at scale.
//!
//! The `sharded_round` series is the one *engine-backed* number here: a
//! wide concurrent training round, flat roster vs a cell-sharded topology
//! (DESIGN.md §15). Cells are bit-neutral (`rust/tests/cells_parity.rs`),
//! so the series tracks pure wall-clock shape.
//!
//! The `async_round` series compares the synchronous barrier against
//! buffered-asynchronous rounds (DESIGN.md §16, `docs/ASYNC.md`) on a
//! straggler-heavy fleet. Its headline `sim_speedup` is *simulated* time —
//! a deterministic number, byte-stable across machines — so it gates
//! cleanly without wall-clock noise.

#[path = "common/mod.rs"]
mod common;

use hasfl::asynch::AsyncSpec;
use hasfl::config::{Config, Range, StrategyKind};
use hasfl::experiment::{Experiment, Preset, Session};
use hasfl::scenario::{ScenarioEngine, ScenarioPreset, ScenarioSim};
use hasfl::util::Json;

fn mega_config(seed: u64) -> Config {
    let mut cfg = Config::table1();
    cfg.fleet.n_devices = ScenarioPreset::MegaFleet.suggested_devices().unwrap();
    cfg.strategy = ScenarioPreset::MegaFleet.suggested_strategy().unwrap();
    cfg.seed = seed;
    cfg
}

fn bench_json_path() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("HASFL_SCENARIO_BENCH_JSON") {
        return p.into();
    }
    if common::smoke() {
        return std::env::temp_dir().join("BENCH_scenario.json");
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scenario.json")
}

/// Build a wide engine-backed session for the `sharded_round` series:
/// Fixed strategy at the cheapest shape (batch 1, cut 1), no scheduled
/// evals or aggregation windows, concurrent rounds.
fn sharded_session(devices: usize, cells: Option<usize>) -> Session {
    let mut b = Experiment::builder()
        .preset(Preset::Small)
        .devices(devices)
        .strategy(StrategyKind::Fixed)
        .fixed_batch(1)
        .fixed_cut(1)
        .rounds(1_000_000)
        .eval_every(1_000_000)
        .agg_interval(1_000_000)
        .engine_pool(0)
        .tune(move |c| {
            c.train.train_samples = devices.max(1024);
            c.train.test_samples = 64;
        })
        .artifacts(common::artifacts_dir())
        .concurrent(true);
    if let Some(n) = cells {
        b = b.cells(n);
    }
    b.build().expect("session")
}

/// Engine-backed concurrent round, flat roster vs an 8-cell topology.
/// Returns the series JSON and the engine-pool width it ran at.
fn sharded_round_series() -> (Json, usize) {
    let devices = if common::smoke() { 32 } else { 128 };
    const CELLS: usize = 8;

    let mut flat = sharded_session(devices, None);
    let width = flat.engine_width();
    let r_flat = common::bench(&format!("sharded_round_flat_n{devices}"), 1, 5, || {
        std::hint::black_box(flat.step().expect("round"));
    });
    flat.finish().expect("finish");

    let mut sharded = sharded_session(devices, Some(CELLS));
    let r_cells = common::bench(&format!("sharded_round_cells{CELLS}_n{devices}"), 1, 5, || {
        std::hint::black_box(sharded.step().expect("round"));
    });
    sharded.finish().expect("finish");

    let mut j = Json::obj();
    j.set("devices", Json::Num(devices as f64))
        .set("cells", Json::Num(CELLS as f64))
        .set("flat", r_flat.to_json_ms())
        .set("sharded", r_cells.to_json_ms())
        .set("speedup_p50", Json::Num(r_flat.summary.p50 / r_cells.summary.p50));
    (j, width)
}

/// A session over a straggler-heavy fleet: two orders of magnitude of
/// compute spread, so the synchronous barrier waits on the tail every
/// round. `buffered` switches the buffered-asynchronous mode on with a
/// buffer of 4 — the sync arm runs the exact same seeded fleet.
fn straggler_session(devices: usize, rounds: usize, buffered: bool) -> Session {
    let mut b = Experiment::builder()
        .preset(Preset::Small)
        .devices(devices)
        .strategy(StrategyKind::Fixed)
        .fixed_batch(1)
        .fixed_cut(1)
        .rounds(rounds)
        .eval_every(1_000_000)
        .agg_interval(2)
        .seed(404)
        .tune(move |c| {
            c.train.train_samples = devices.max(256);
            c.train.test_samples = 64;
            c.fleet.flops = Range::new(2e10, 2e12);
        })
        .artifacts(common::artifacts_dir());
    if buffered {
        b = b.async_spec(AsyncSpec { buffer_k: 4, max_staleness: 8, decay: 0.5 });
    }
    b.build().expect("session")
}

/// Synchronous barrier vs buffered-async flushes on the straggler fleet.
/// Tracks simulated seconds per round for both arms (plus the wall clock
/// each arm took end to end, as context — not gated). The async arm must
/// beat the barrier: a flush waits on its 4th completion, never the
/// slowest device.
fn async_round_series() -> Json {
    let (devices, rounds) = if common::smoke() { (8, 4) } else { (16, 12) };

    let mut sync = straggler_session(devices, rounds, false);
    let t0 = std::time::Instant::now();
    let mut sync_sim = 0.0;
    while !sync.is_done() {
        sync_sim = sync.step().expect("sync round").sim_time;
    }
    let sync_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    sync.finish().expect("finish");

    let mut buffered = straggler_session(devices, rounds, true);
    let t0 = std::time::Instant::now();
    let (mut async_sim, mut flushed, mut drops) = (0.0, 0usize, 0usize);
    let mut stale_mean_sum = 0.0;
    while !buffered.is_done() {
        let r = buffered.step().expect("async round");
        async_sim = r.sim_time;
        if let Some(a) = r.asynchrony {
            flushed += a.flushed;
            drops += a.dropped_stale;
            stale_mean_sum += a.staleness_mean;
        }
    }
    let async_wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    buffered.finish().expect("finish");

    let sync_per_round = sync_sim / rounds as f64;
    let async_per_round = async_sim / rounds as f64;
    let speedup = sync_per_round / async_per_round;
    assert!(
        speedup > 1.0,
        "buffered-async must beat the synchronous barrier on a straggler fleet \
         (sync {sync_per_round:.3} s/round vs async {async_per_round:.3} s/round)"
    );
    println!(
        "async_round: sync {sync_per_round:.3} s/round | async {async_per_round:.3} s/round | \
         sim speedup {speedup:.2}x | flushed {flushed} | stale drops {drops}"
    );

    let mut j = Json::obj();
    j.set("devices", Json::Num(devices as f64))
        .set("rounds", Json::Num(rounds as f64))
        .set("buffer_k", Json::Num(4.0))
        .set("sim_s_per_round_sync", Json::Num(sync_per_round))
        .set("sim_s_per_round_async", Json::Num(async_per_round))
        .set("sim_speedup", Json::Num(speedup))
        .set("flushed_total", Json::Num(flushed as f64))
        .set("stale_drops_total", Json::Num(drops as f64))
        .set("staleness_mean_per_round", Json::Num(stale_mean_sum / rounds as f64))
        .set("wall_ms_sync", Json::Num(sync_wall_ms))
        .set("wall_ms_async", Json::Num(async_wall_ms));
    j
}

fn main() {
    let cfg = mega_config(2025);
    let n = cfg.fleet.n_devices;

    // Engine-only cost: one fleet evolution step at 1k+ devices.
    let mut engine =
        ScenarioEngine::new(ScenarioPreset::MegaFleet.scenario(), cfg.sample_fleet(), cfg.seed)
            .expect("engine");
    let r_advance = common::bench(&format!("megafleet_engine_advance_n{n}"), 2, 20, || {
        std::hint::black_box(engine.advance());
    });

    // Full analytic round: evolution + (membership-driven) re-solve +
    // subset latency. Five rounds per iteration — in smoke mode this is
    // exactly the 5-round mega-fleet completion check.
    let mut sim = ScenarioSim::new(mega_config(2025), ScenarioPreset::MegaFleet.scenario())
        .expect("sim");
    let r_rounds = common::bench(&format!("megafleet_5rounds_n{n}"), 1, 8, || {
        for _ in 0..5 {
            std::hint::black_box(sim.step());
        }
    });

    let trace = sim.trace();
    assert!(trace.len() >= 5, "mega-fleet smoke must complete 5 rounds");
    let split = trace.split_summary().expect("rounds");
    let drift = trace.drift_summary().expect("rounds");
    println!(
        "megafleet: rounds {} | active(final) {} | partial rounds {} | re-solves {}",
        trace.len(),
        trace.rounds.last().map_or(0, |r| r.n_active),
        trace.partial_rounds(),
        trace.resolves()
    );

    // Engine-backed series last: they spawn engine pools.
    let async_round = async_round_series();
    let (sharded, pool_width) = sharded_round_series();

    let mut j = Json::obj();
    j.set("bench", Json::Str("scenario_fleet".into()))
        .set("meta", common::meta_json(pool_width))
        .set("smoke", Json::Bool(common::smoke()))
        .set("sharded_round", sharded)
        .set("async_round", async_round)
        .set("fleet", Json::Num(n as f64))
        .set("rounds_run", Json::Num(trace.len() as f64))
        .set("engine_advance", r_advance.to_json_ms())
        .set("five_rounds", r_rounds.to_json_ms())
        .set("resolves", Json::Num(trace.resolves() as f64))
        .set("partial_rounds", Json::Num(trace.partial_rounds() as f64))
        .set("t_split_p50_s", Json::Num(split.p50))
        .set("t_split_p95_s", Json::Num(split.p95))
        .set("drift_p50", Json::Num(drift.p50))
        .set("drift_max", Json::Num(drift.max));

    let path = bench_json_path();
    std::fs::write(&path, j.dump()).expect("write bench json");
    println!("bench report -> {}", path.display());
}
