//! Shared mini bench harness (criterion is unavailable offline): warmup +
//! timed iterations, reporting min/median/mean like `cargo bench` output.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<42} iters {:>5}  min {:>12}  median {:>12}  mean {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        median_ns: median,
        mean_ns: mean,
        min_ns: samples[0],
    };
    res.print();
    res
}

/// Locate the artifacts dir, or None (benches degrade gracefully).
#[allow(dead_code)] // not every bench needs artifacts
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        println!("SKIP (no artifacts; run `make artifacts`)");
        None
    }
}
