//! Shared mini bench harness (criterion is unavailable offline): warmup +
//! timed iterations, reporting min/p50/mean/p95 like `cargo bench` output,
//! with a machine-readable JSON form for the `BENCH_*.json` trajectory
//! files and a CI smoke mode (`HASFL_BENCH_SMOKE=1`: one iteration, no
//! warmup, no timing assertions — it only proves the harness still runs).

use std::time::Instant;

use hasfl::metrics::LatencySummary;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Percentile summary of the timed iterations, in nanoseconds.
    pub summary: LatencySummary,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<42} iters {:>5}  min {:>12}  p50 {:>12}  mean {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.summary.min),
            fmt_ns(self.summary.p50),
            fmt_ns(self.summary.mean),
            fmt_ns(self.summary.p95)
        );
    }

    /// JSON object with millisecond-scaled percentiles.
    #[allow(dead_code)] // only the benches that emit BENCH_*.json use this
    pub fn to_json_ms(&self) -> hasfl::util::Json {
        self.summary.scaled(1e-6).to_json("ms")
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Whether CI smoke mode is active.
pub fn smoke() -> bool {
    std::env::var("HASFL_BENCH_SMOKE").is_ok()
}

/// `(warmup, iters)` honouring smoke mode (one bare iteration there).
pub fn iters_for(warmup: usize, iters: usize) -> (usize, usize) {
    if smoke() {
        (0, 1)
    } else {
        (warmup, iters)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs (both reduced to a
/// single bare iteration in smoke mode).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    let (warmup, iters) = iters_for(warmup, iters);
    bench_raw(name, warmup, iters, f)
}

/// Like [`bench`] but the iteration counts are taken literally, ignoring
/// smoke mode. The CI-gated kernel series uses this: `ci.sh` asserts on
/// its `speedup_p50`, and a single smoke sample is too noisy to gate on,
/// so that bench picks its own (small) smoke counts instead.
#[allow(dead_code)]
pub fn bench_raw<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let summary = LatencySummary::from_samples(&samples).expect("iters >= 1");
    let res = BenchResult { name: name.to_string(), iters, summary };
    res.print();
    res
}

/// Bench-environment metadata (the `meta` block of `BENCH_*.json`): the
/// facts that must match for two reports to compare like-for-like.
/// `hasfl bench-diff` warns on any mismatch here and never gates on it.
#[allow(dead_code)]
pub fn meta_json(pool_width: usize) -> hasfl::util::Json {
    use hasfl::util::Json;
    let mut j = Json::obj();
    j.set("pool_width", Json::Num(pool_width as f64))
        .set("host_cores", Json::Num(hasfl::util::host_cores() as f64));
    j
}

/// The artifacts directory (may or may not hold an AOT manifest).
#[allow(dead_code)] // not every bench needs an engine
pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The backend this bench run resolves to: `HASFL_BACKEND` if set, else
/// PJRT when artifacts exist, else native. Engine benches never skip —
/// the native backend runs on any machine (DESIGN.md §11), which is what
/// keeps `BENCH_e2e.json` flowing from artifact-less CI runners.
#[allow(dead_code)]
pub fn backend() -> hasfl::backend::BackendKind {
    hasfl::backend::BackendKind::from_env()
        .unwrap_or(hasfl::backend::BackendKind::Auto)
        .resolve(&artifacts_dir())
}

/// Spawn a single-lane engine + manifest on the resolved backend.
#[allow(dead_code)]
pub fn engine_setup() -> (hasfl::runtime::EngineHandle, hasfl::model::Manifest) {
    let spec = hasfl::runtime::EngineSpec::resolve(backend(), &artifacts_dir(), 10);
    let manifest = spec.manifest().expect("manifest");
    let engine = hasfl::runtime::EngineHandle::spawn_backend(spec, 1).expect("engine");
    (engine, manifest)
}
