#!/usr/bin/env bash
# Tier-1 verification gate (also available as `make check`). Hosted CI
# (.github/workflows/ci.yml) runs this exact script on push + PR — it is
# the gate of record.
#
# Runs the full local CI battery over the Rust workspace:
#   1. release build        (binaries + examples + benches must compile)
#   2. test suite           (engine-backed tests self-skip without artifacts;
#                            includes the scenario-determinism suite)
#   3. formatting           (cargo fmt --check)
#   4. lints                (cargo clippy -D warnings)
#   5. dependency gate      (cargo deny check; skipped if not installed)
#   6. bench smoke          (1 iteration: e2e_round + mega-fleet scenario)
#   7. example smoke        (churn_fleet end-to-end under HASFL_BENCH_SMOKE)
#   8. resume smoke         (train 3 rounds -> checkpoint -> resume 2 more;
#                            history must be byte-identical to 5 straight
#                            rounds; skipped without AOT artifacts)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release --all-targets

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== dependency gate (make check-deps) =="
make -C .. check-deps

echo "== bench smoke (1 iteration, no timing assertions) =="
make -C .. bench-smoke

echo "== churn_fleet example smoke (determinism + liveness asserts) =="
HASFL_BENCH_SMOKE=1 cargo run --release --example churn_fleet

echo "== checkpoint resume smoke (train 3 + resume 2 == straight 5) =="
if [ -f artifacts/manifest.json ]; then
  CKPT_TMP=$(mktemp -d)
  # Straight 5-round run, checkpointing at round 3 along the way.
  ./target/release/hasfl train --preset small --rounds 5 --seed 1234 \
    --checkpoint-every 3 --checkpoint-dir "$CKPT_TMP/ck" \
    --out "$CKPT_TMP/straight.csv"
  # Warm restart from the round-3 checkpoint; the CSV holds the restored
  # rounds 1-3 plus the replayed rounds 4-5 and must be byte-identical.
  ./target/release/hasfl train --resume "$CKPT_TMP/ck/ckpt_round_000003.hckpt" \
    --out "$CKPT_TMP/resumed.csv"
  cmp "$CKPT_TMP/straight.csv" "$CKPT_TMP/resumed.csv"
  rm -rf "$CKPT_TMP"
  echo "resume smoke OK (bit-identical histories)"
else
  echo "no AOT artifacts; resume smoke skipped (run 'make artifacts')"
fi

echo "CI OK"
