#!/usr/bin/env bash
# Tier-1 verification gate (also available as `make check`).
#
# Runs the full local CI battery over the Rust workspace:
#   1. release build        (binaries + examples + benches must compile)
#   2. test suite           (engine-backed tests self-skip without artifacts)
#   3. formatting           (cargo fmt --check)
#   4. lints                (cargo clippy -D warnings)
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release --all-targets

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== bench smoke (1 iteration, no timing assertions) =="
HASFL_BENCH_SMOKE=1 cargo bench --bench e2e_round

echo "CI OK"
