#!/usr/bin/env bash
# Tier-1 verification gate (also available as `make check`). Hosted CI
# (.github/workflows/ci.yml) runs this exact script on push + PR — it is
# the gate of record, on the *native* backend with HASFL_REQUIRE_ENGINE=1
# so no step and no engine-backed test can silently skip.
#
# Usage: ./ci.sh [--backend auto|native|pjrt]
#   auto   (default) pjrt when rust/artifacts/manifest.json exists, else native
#   native pure-Rust engine: the full battery runs with no AOT artifacts,
#          no Python, no XLA toolchain (DESIGN.md §11)
#   pjrt   AOT artifacts required (build with `make artifacts`)
# The choice is exported as HASFL_BACKEND, which every test, bench, and
# example honours. HASFL_REQUIRE_ENGINE=1 additionally turns any
# engine-backed test skip into a hard failure (PJRT-specific parity halves
# still skip without artifacts — the non-blocking `pjrt-parity` CI job
# covers those).
#
# Runs the full local CI battery over the Rust workspace:
#   1. release build        (binaries + examples + benches must compile)
#   2. test suite           (engine-backed suites run on the selected
#                            backend — never skipped; includes the
#                            scenario-determinism + backend-parity suites)
#   3. formatting           (cargo fmt --check)
#   4. lints                (cargo clippy -D warnings)
#   5. rustdoc gate         (cargo doc --no-deps with warnings denied:
#                            every public item documented, no broken
#                            intra-doc links)
#   6. dependency gate      (cargo deny check; skipped if not installed)
#   7. bench smoke          (1 iteration: e2e_round + mega-fleet scenario;
#                            BENCH_e2e.json and BENCH_scenario.json must
#                            both be emitted — the perf trajectory is
#                            never silently empty — and the kernel_native
#                            series must show the blocked/tiled GEMM >= 2x
#                            over the naive reference, DESIGN.md §14)
#   8. example smoke        (churn_fleet end-to-end under HASFL_BENCH_SMOKE)
#   9. resume smoke         (train 3 rounds -> checkpoint -> resume 2 more;
#                            history must be byte-identical to 5 straight
#                            rounds; runs on every backend)
#  10. serve smoke          (hasfl serve: create a session over HTTP, run 3
#                            rounds, SIGTERM the daemon, restart it on the
#                            same state dir, run the rest; the served
#                            history.csv must be byte-identical to a solo
#                            run — DESIGN.md §12)
#  11. json/bench-diff smoke (hasfl info --json parses; hasfl bench-diff
#                            gates BENCH_*.json tail-latency regressions)
#  12. chaos smoke          (the same seeded --faults chaos run twice must
#                            be byte-identical; then slow-loris + mid-body
#                            disconnect probes against a tightly-capped
#                            daemon must leave /healthz responsive —
#                            DESIGN.md §13)
#  13. sharded 10k smoke    (a 10,000-device engine-backed round on the
#                            native backend, flat vs an 8-cell topology;
#                            the two histories must be byte-identical —
#                            hierarchical aggregation is bit-neutral,
#                            DESIGN.md §15)
#  14. async smoke          (the same seeded --async-buffer run twice must
#                            be byte-identical; the BENCH_scenario.json
#                            async_round series must show buffered-async
#                            beating the synchronous barrier on a
#                            straggler fleet in simulated time; and every
#                            CLI flag in main.rs must be documented in
#                            README.md — DESIGN.md §16)
set -euo pipefail

BACKEND=auto
while [ $# -gt 0 ]; do
  case "$1" in
    --backend) BACKEND="$2"; shift 2 ;;
    --backend=*) BACKEND="${1#--backend=}"; shift ;;
    *) echo "usage: ./ci.sh [--backend auto|native|pjrt]" >&2; exit 2 ;;
  esac
done
case "$BACKEND" in
  auto|native|pjrt) ;;
  *) echo "unknown backend '$BACKEND' (expected auto|native|pjrt)" >&2; exit 2 ;;
esac

ROOT=$(cd "$(dirname "$0")" && pwd)
cd "$ROOT/rust"
export HASFL_BACKEND="$BACKEND"

echo "== backend: $BACKEND | HASFL_REQUIRE_ENGINE=${HASFL_REQUIRE_ENGINE:-unset} =="

echo "== cargo build --release =="
cargo build --release --all-targets

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== rustdoc gate (cargo doc --no-deps, warnings denied) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== dependency gate (make check-deps) =="
make -C .. check-deps

echo "== bench smoke (1 iteration, no timing assertions) =="
export HASFL_BENCH_JSON="$ROOT/BENCH_e2e.json"
export HASFL_SCENARIO_BENCH_JSON="$ROOT/BENCH_scenario.json"
rm -f "$HASFL_BENCH_JSON" "$HASFL_SCENARIO_BENCH_JSON"
make -C .. bench-smoke
test -f "$HASFL_BENCH_JSON" || { echo "FAIL: e2e bench emitted no BENCH_e2e.json"; exit 1; }
test -f "$HASFL_SCENARIO_BENCH_JSON" || { echo "FAIL: scenario bench emitted no BENCH_scenario.json"; exit 1; }
# The kernel-level series must show the blocked/tiled GEMM beating the
# naive reference by at least 2x (typical: 3-8x; the conservative floor
# absorbs shared-runner noise while still catching a scalar fallback).
python3 - "$HASFL_BENCH_JSON" <<'PY'
import json, sys
kn = json.load(open(sys.argv[1]))["kernel_native"]
s = kn["speedup_p50"]
print("kernel_native: naive p50 %.2f ms -> tiled p50 %.2f ms (%.2fx, %d threads)"
      % (kn["naive"]["p50_ms"], kn["tiled"]["p50_ms"], s, kn["threads"]))
assert s >= 2.0, "tiled GEMM speedup %.2fx is under the 2.0x floor" % s
PY
echo "perf trajectory OK: BENCH_e2e.json + BENCH_scenario.json"

echo "== churn_fleet example smoke (determinism + liveness asserts) =="
HASFL_BENCH_SMOKE=1 cargo run --release --example churn_fleet

echo "== checkpoint resume smoke (train 3 + resume 2 == straight 5) =="
CKPT_TMP=$(mktemp -d)
# Straight 5-round run, checkpointing at round 3 along the way.
./target/release/hasfl train --preset small --rounds 5 --seed 1234 \
  --backend "$BACKEND" \
  --checkpoint-every 3 --checkpoint-dir "$CKPT_TMP/ck" \
  --out "$CKPT_TMP/straight.csv"
# Warm restart from the round-3 checkpoint; the checkpoint embeds the
# resolved backend, so no --backend flag here. The CSV holds the restored
# rounds 1-3 plus the replayed rounds 4-5 and must be byte-identical.
./target/release/hasfl train --resume "$CKPT_TMP/ck/ckpt_round_000003.hckpt" \
  --out "$CKPT_TMP/resumed.csv"
cmp "$CKPT_TMP/straight.csv" "$CKPT_TMP/resumed.csv"
rm -rf "$CKPT_TMP"
echo "resume smoke OK (bit-identical histories)"

echo "== serve smoke (create/run over HTTP -> SIGTERM -> adopt -> byte-identical) =="
SERVE_TMP=$(mktemp -d)
# The reference: an uninterrupted 5-round solo run of the same config.
./target/release/hasfl train --preset small --rounds 5 --seed 4242 \
  --backend "$BACKEND" --out "$SERVE_TMP/solo.csv"
serve_start() {
  rm -f "$SERVE_TMP/state/daemon.addr"
  ./target/release/hasfl serve --addr 127.0.0.1:0 \
    --state-dir "$SERVE_TMP/state" --workers 2 &
  SERVE_PID=$!
  ADDR=""
  for _ in $(seq 1 100); do
    if [ -f "$SERVE_TMP/state/daemon.addr" ]; then
      ADDR=$(cat "$SERVE_TMP/state/daemon.addr"); break
    fi
    sleep 0.1
  done
  [ -n "$ADDR" ] || { echo "FAIL: serve daemon did not come up"; exit 1; }
}
serve_start
curl -sf "http://$ADDR/healthz" > /dev/null
curl -sf -X POST "http://$ADDR/sessions" \
  -d '{"preset":"small","rounds":5,"seed":4242,"checkpoint_every":3,"run":3}' > /dev/null
curl -sf "http://$ADDR/sessions/1/wait?round=3&timeout_ms=300000" > /dev/null
# SIGTERM mid-experiment: the daemon checkpoints the live session on the
# way down; the restarted daemon adopts it from the state dir at round 3.
kill -TERM "$SERVE_PID"; wait "$SERVE_PID"
serve_start
curl -sf -X POST "http://$ADDR/sessions/1/run" -d '{}' > /dev/null
curl -sf "http://$ADDR/sessions/1/wait?round=5&timeout_ms=300000" > /dev/null
curl -sf "http://$ADDR/sessions/1/history.csv" -o "$SERVE_TMP/served.csv"
kill -TERM "$SERVE_PID"; wait "$SERVE_PID"
cmp "$SERVE_TMP/solo.csv" "$SERVE_TMP/served.csv"
rm -rf "$SERVE_TMP"
echo "serve smoke OK (adopted history byte-identical to the solo run)"

echo "== info --json + bench-diff smoke =="
./target/release/hasfl info --json --backend "$BACKEND" | python3 -c \
  'import json,sys; d=json.load(sys.stdin); assert d["service"] == "hasfl", d'
# Self-comparison: every shared leaf has delta 0, so the gate must pass.
./target/release/hasfl bench-diff --base "$HASFL_BENCH_JSON" --head "$HASFL_BENCH_JSON"
echo "json/bench-diff smoke OK"

echo "== chaos smoke (seeded faults deterministic + hostile-client probes) =="
CHAOS_TMP=$(mktemp -d)
# The same seeded chaos run twice must be byte-identical: retries,
# abandonments, quarantines, and lane respawns are pure functions of
# (seed, round) — DESIGN.md §13.
./target/release/hasfl train --preset small --rounds 4 --seed 77 \
  --backend "$BACKEND" --faults chaos --out "$CHAOS_TMP/a.csv"
./target/release/hasfl train --preset small --rounds 4 --seed 77 \
  --backend "$BACKEND" --faults chaos --out "$CHAOS_TMP/b.csv"
cmp "$CHAOS_TMP/a.csv" "$CHAOS_TMP/b.csv"
# Hostile-client probes against a tightly-capped daemon: a slow-loris
# sender and a mid-body disconnect must both be shed by the socket
# timeouts while /healthz keeps answering, and the daemon must still
# shut down cleanly afterwards (no unwrap panics anywhere in serve).
rm -f "$CHAOS_TMP/state/daemon.addr"
./target/release/hasfl serve --addr 127.0.0.1:0 --state-dir "$CHAOS_TMP/state" \
  --workers 1 --max-conns 8 --io-timeout-ms 500 &
CHAOS_PID=$!
ADDR=""
for _ in $(seq 1 100); do
  if [ -f "$CHAOS_TMP/state/daemon.addr" ]; then
    ADDR=$(cat "$CHAOS_TMP/state/daemon.addr"); break
  fi
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "FAIL: chaos serve daemon did not come up"; exit 1; }
python3 - "$ADDR" <<'PY'
import socket, sys, time
host, port = sys.argv[1].rsplit(":", 1)

def healthz():
    s = socket.create_connection((host, int(port)), timeout=5)
    s.sendall(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n")
    data = b""
    while True:
        chunk = s.recv(4096)
        if not chunk:
            break
        data += chunk
    s.close()
    assert data.startswith(b"HTTP/1.1 200"), data[:200]

# Slow-loris: trickle a few bytes of request line, then stall.
loris = socket.create_connection((host, int(port)), timeout=5)
loris.sendall(b"GET /hea")
healthz()  # the daemon answers around the stalled connection
# Mid-body disconnect: promise 64 body bytes, send 9, hang up.
torn = socket.create_connection((host, int(port)), timeout=5)
torn.sendall(b"POST /sessions HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"name\": ")
torn.close()
time.sleep(0.8)  # past --io-timeout-ms: the loris thread is reclaimed
healthz()
loris.close()
print("hostile-client probes OK")
PY
kill -TERM "$CHAOS_PID"; wait "$CHAOS_PID"
rm -rf "$CHAOS_TMP"
echo "chaos smoke OK (deterministic faults; daemon survived hostile clients)"

echo "== sharded 10k smoke (cells=1 vs cells=8, byte-identical histories) =="
SHARD_TMP=$(mktemp -d)
# 10,000 devices through the real engine in one round, at the cheapest
# executable shape (Fixed strategy, batch 1, cut 1, no scheduled eval).
# Always on the native backend: the shard smoke is about coordinator
# scale, not AOT artifacts.
./target/release/hasfl config --preset small --out "$SHARD_TMP/wide.json"
python3 - "$SHARD_TMP/wide.json" <<'PY'
import json, sys
p = sys.argv[1]
cfg = json.load(open(p))
cfg["fleet"]["n_devices"] = 10000
cfg["strategy"] = "fixed"
cfg["fixed_batch"] = 1
cfg["fixed_cut"] = 1
cfg["train"]["rounds"] = 1
cfg["train"]["eval_every"] = 1000      # skip scheduled eval
cfg["train"]["agg_interval"] = 1000    # no forged-aggregation round
cfg["train"]["train_samples"] = 10000  # >= n_devices (one sample each)
json.dump(cfg, open(p, "w"))
PY
HASFL_BACKEND=native ./target/release/hasfl train --config "$SHARD_TMP/wide.json" \
  --backend native --cells 1 --concurrent --out "$SHARD_TMP/cells1.csv"
HASFL_BACKEND=native ./target/release/hasfl train --config "$SHARD_TMP/wide.json" \
  --backend native --cells 8 --concurrent --out "$SHARD_TMP/cells8.csv"
cmp "$SHARD_TMP/cells1.csv" "$SHARD_TMP/cells8.csv"
rm -rf "$SHARD_TMP"
echo "sharded 10k smoke OK (flat and 8-cell histories byte-identical)"

echo "== async smoke (deterministic buffered rounds + straggler speedup + docs drift) =="
ASYNC_TMP=$(mktemp -d)
# The same seeded buffered-async run twice must be byte-identical: the
# completion schedule is simulated from the config seed, never measured
# off the wall clock — DESIGN.md §16.
./target/release/hasfl train --preset small --rounds 4 --seed 88 \
  --backend "$BACKEND" --async-buffer 2 --out "$ASYNC_TMP/a.csv"
./target/release/hasfl train --preset small --rounds 4 --seed 88 \
  --backend "$BACKEND" --async-buffer 2 --out "$ASYNC_TMP/b.csv"
cmp "$ASYNC_TMP/a.csv" "$ASYNC_TMP/b.csv"
rm -rf "$ASYNC_TMP"
# The scenario bench (step 7) ran sync vs buffered-async over the same
# straggler-heavy fleet; its headline is simulated time, so the gate is
# deterministic. A flush waits on its K-th completion, never the slowest
# device, so the speedup must clear 1x on any machine.
python3 - "$HASFL_SCENARIO_BENCH_JSON" <<'PY'
import json, sys
ar = json.load(open(sys.argv[1]))["async_round"]
s = ar["sim_speedup"]
print("async_round: sync %.3f s/round -> async %.3f s/round (%.2fx simulated speedup, "
      "%d flushed, %d stale drops)"
      % (ar["sim_s_per_round_sync"], ar["sim_s_per_round_async"], s,
         ar["flushed_total"], ar["stale_drops_total"]))
assert s > 1.0, "buffered-async did not beat the synchronous barrier (%.2fx)" % s
PY
# Docs drift gate: every CLI flag the binary actually reads must appear in
# README.md. Flag names are extracted from the argument accessors in
# main.rs, so adding a flag without documenting it fails CI.
DOC_DRIFT=0
for f in $(grep -o 'args\.\(get\|flag\|get_or\|get_opt::<[a-zA-Z0-9]*>\)("[a-z-]*"' src/main.rs \
  | sed 's/.*("\([a-z-]*\)".*/\1/' | sort -u); do
  grep -q -- "--$f" ../README.md || { echo "FAIL: --$f is undocumented in README.md"; DOC_DRIFT=1; }
done
[ "$DOC_DRIFT" -eq 0 ] || exit 1
echo "async smoke OK (deterministic buffer; straggler speedup; README covers every flag)"

echo "CI OK (backend: $BACKEND)"
