# Top-level targets. `make check` is the tier-1 gate (see ROADMAP.md).

.PHONY: check artifacts artifacts100 test bench-smoke

check:
	./ci.sh

# One-iteration bench run (no timing assertions): proves the bench harness
# and its BENCH_*.json emission still work. Wired into ci.sh.
bench-smoke:
	cd rust && HASFL_BENCH_SMOKE=1 cargo bench --bench e2e_round

# AOT-lower the SplitCNN-8 fwd/bwd artifacts consumed by the PJRT runtime.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

# 100-class variant for the fig5 CIFAR-100-like panels.
artifacts100:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts100 --classes 100

test:
	cd rust && cargo test -q
