# Top-level targets. `make check` is the tier-1 gate (see ROADMAP.md);
# hosted CI (.github/workflows/ci.yml) runs the same ./ci.sh battery on
# the native backend with HASFL_REQUIRE_ENGINE=1 (no skip paths).

.PHONY: check check-native check-pjrt check-deps artifacts artifacts100 test bench-smoke bench-diff doc serve

# Full battery on the locally-sensible backend: pjrt when AOT artifacts
# exist, the artifact-free native backend otherwise (so a fresh checkout
# with no Python/JAX still runs the *complete* gate, nothing skipped).
check:
	@if [ -f rust/artifacts/manifest.json ]; then \
		./ci.sh --backend auto; \
	else \
		echo "no AOT artifacts: running the artifact-free native battery"; \
		HASFL_REQUIRE_ENGINE=1 ./ci.sh --backend native; \
	fi

# Artifact-free full battery (what hosted CI's gate of record runs):
# every engine-backed suite, the e2e bench, and the resume smoke on the
# pure-Rust backend, with skips promoted to failures.
check-native:
	HASFL_REQUIRE_ENGINE=1 ./ci.sh --backend native

# Full battery pinned to PJRT (requires `make artifacts` first).
check-pjrt:
	./ci.sh --backend pjrt

# License/advisory gate over the dependency graph (rust/deny.toml). Skips
# with a notice when cargo-deny is not installed (the offline dev image);
# hosted CI installs it, so new dependencies are gated on every PR.
check-deps:
	@cd rust && if command -v cargo-deny >/dev/null 2>&1; then \
		cargo deny check; \
	else \
		echo "cargo-deny not installed; skipping dependency gate"; \
		echo "(hosted CI runs it; locally: cargo install cargo-deny --locked)"; \
	fi

# One-iteration bench run (no timing assertions): proves the bench harness
# and its BENCH_*.json emission still work, and that the mega-fleet
# scenario (>= 1000 devices) completes a 5-round smoke. Wired into ci.sh.
bench-smoke:
	cd rust && HASFL_BENCH_SMOKE=1 cargo bench --bench e2e_round
	cd rust && HASFL_BENCH_SMOKE=1 cargo bench --bench scenario_fleet

# Compare two bench reports (the BENCH_*.json files ci.sh's bench smoke
# emits) and fail when a p50/p95 leaf regressed beyond MAX_REGRESS percent:
#   make bench-diff BASE=BENCH_e2e.base.json HEAD=BENCH_e2e.json
MAX_REGRESS ?= 25
bench-diff:
	@test -n "$(BASE)" -a -n "$(HEAD)" || \
		{ echo "usage: make bench-diff BASE=a.json HEAD=b.json [MAX_REGRESS=25]"; exit 2; }
	cd rust && cargo run --release --bin hasfl -- bench-diff \
		--base "$(abspath $(BASE))" --head "$(abspath $(HEAD))" --max-regress "$(MAX_REGRESS)"

# API docs with the same strictness ci.sh enforces: every public item
# documented (lib.rs carries #![warn(missing_docs)]) and no broken
# intra-doc links, with rustdoc warnings denied.
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Run the training daemon on its defaults (127.0.0.1:4780, ./serve-state).
serve:
	cd rust && cargo run --release --bin hasfl -- serve

# AOT-lower the SplitCNN-8 fwd/bwd artifacts consumed by the PJRT runtime.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

# 100-class variant for the fig5 CIFAR-100-like panels.
artifacts100:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts100 --classes 100

test:
	cd rust && cargo test -q
