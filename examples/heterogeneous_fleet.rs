//! Heterogeneous-fleet comparison (the Fig 5 scenario in miniature):
//! trains SplitCNN-8 under HASFL and the paper's four benchmarks on the
//! same heterogeneous fleet and reports accuracy-vs-simulated-time plus
//! converged time, demonstrating the straggler mitigation the paper's
//! intro motivates.
//!
//! ```bash
//! make artifacts && cargo run --release --example heterogeneous_fleet -- [rounds]
//! ```

use hasfl::config::StrategyKind;
use hasfl::experiment::{Experiment, Preset};

fn main() -> hasfl::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let strategies = [
        StrategyKind::Hasfl,
        StrategyKind::RbsHams,
        StrategyKind::HabsRms,
        StrategyKind::RbsRms,
        StrategyKind::RbsRhams,
    ];

    println!("HASFL vs benchmarks ({} rounds each, N=4, non-IID)\n", rounds);
    let mut summary = Vec::new();
    for kind in strategies {
        let mut session = Experiment::builder()
            .preset(Preset::Small)
            .devices(4)
            .rounds(rounds)
            .non_iid()
            .strategy(kind)
            .artifacts("artifacts")
            .build()?;
        session.run_to_completion()?;
        let (_, time, acc) = session
            .history()
            .converged_or_last()
            .expect("eval points exist");
        let best = session.history().best_acc().unwrap_or(acc);
        println!(
            "{:<12} sim_time {:>9.2}s  best acc {:>6.2}%  final decisions b={:?} cut={:?}",
            kind.as_str(),
            time,
            best * 100.0,
            session.decisions().batch,
            session.decisions().cut
        );
        summary.push((kind, time, best));
        session.finish()?;
    }

    let hasfl = summary.iter().find(|(k, _, _)| *k == StrategyKind::Hasfl).unwrap();
    let worst = summary
        .iter()
        .filter(|(k, _, _)| *k != StrategyKind::Hasfl)
        .map(|&(_, t, _)| t)
        .fold(0.0f64, f64::max);
    println!(
        "\nHASFL simulated convergence speedup vs slowest benchmark: {:.1}x",
        worst / hasfl.1.max(1e-9)
    );
    Ok(())
}
