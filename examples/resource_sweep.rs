//! Resource-robustness sweep (the Fig 7/8 scenario) at paper scale:
//! evaluates the *analytic* converged-time objective Θ′ for HASFL and the
//! benchmarks on VGG-16 with N=20 Table-I devices while scaling device
//! compute and uplink bandwidth. Pure latency-model + convergence-bound
//! math — no model execution — so it runs in milliseconds.
//!
//! ```bash
//! cargo run --release --example resource_sweep
//! ```

use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::Experiment;
use hasfl::figures::analytic_converged_time;

fn main() -> hasfl::Result<()> {
    // Validated analytic base config (Table I, VGG-16 profile).
    let base = Experiment::builder().config(Config::table1()).build_config()?;
    let strategies = [
        StrategyKind::Hasfl,
        StrategyKind::RbsHams,
        StrategyKind::HabsRms,
        StrategyKind::RbsRms,
        StrategyKind::RbsRhams,
    ];

    println!("Estimated time-to-convergence (hours), VGG-16, N=20, Table I\n");

    println!("== device compute scale (Fig 7a) ==");
    print!("{:>8}", "scale");
    for k in strategies {
        print!("{:>12}", k.as_str());
    }
    println!();
    for scale in [0.5f64, 1.0, 2.0] {
        let mut cfg = base.clone();
        cfg.fleet.flops = cfg.fleet.flops.scale(scale);
        print!("{scale:>8.1}");
        for k in strategies {
            match analytic_converged_time(&cfg, k, 1.0, 8) {
                Some(v) => print!("{:>12.2}", v / 3600.0),
                None => print!("{:>12}", "inf"),
            }
        }
        println!();
    }

    println!("\n== device uplink scale (Fig 8a) ==");
    print!("{:>8}", "scale");
    for k in strategies {
        print!("{:>12}", k.as_str());
    }
    println!();
    for scale in [0.25f64, 0.5, 1.0, 2.0] {
        let mut cfg = base.clone();
        cfg.fleet.up_bps = cfg.fleet.up_bps.scale(scale);
        print!("{scale:>8.2}");
        for k in strategies {
            match analytic_converged_time(&cfg, k, 1.0, 8) {
                Some(v) => print!("{:>12.2}", v / 3600.0),
                None => print!("{:>12}", "inf"),
            }
        }
        println!();
    }

    println!("\nShapes to check against the paper: HASFL lowest everywhere;");
    println!("RBS+RMS degrades fastest as resources shrink; the HASFL curve");
    println!("is nearly flat (heterogeneity-aware BS+MS adapts to the fleet).");
    Ok(())
}
