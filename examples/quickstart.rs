//! Quickstart: the end-to-end driver required by DESIGN.md §7.
//!
//! Trains SplitCNN-8 with the full HASFL stack — Pallas-kernel AOT
//! artifacts through the PJRT runtime, heterogeneity-aware BS+MS
//! re-optimized every I rounds, simulated Table-I edge network — on the
//! synthetic CIFAR-like corpus, and logs the loss curve + test accuracy.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hasfl::config::{Config, StrategyKind};
use hasfl::coordinator::Trainer;

fn main() -> hasfl::Result<()> {
    let mut cfg = Config::small(); // N=4 heterogeneous devices, 200 rounds
    cfg.strategy = StrategyKind::Hasfl;

    println!("HASFL quickstart");
    println!(
        "  fleet: {} devices, {:.1}-{:.1} TFLOPS, uplink {:.0}-{:.0} Mbps",
        cfg.fleet.n_devices,
        cfg.fleet.flops.lo / 1e12,
        cfg.fleet.flops.hi / 1e12,
        cfg.fleet.up_bps.lo / 1e6,
        cfg.fleet.up_bps.hi / 1e6
    );
    println!(
        "  train: {} rounds, I={}, lr={}, eps={}",
        cfg.train.rounds, cfg.train.agg_interval, cfg.train.lr, cfg.train.epsilon
    );

    let mut trainer = Trainer::new(cfg, std::path::Path::new("artifacts"))?;
    println!(
        "  initial decisions: b={:?} cut={:?}",
        trainer.dec.batch, trainer.dec.cut
    );

    let rounds = trainer.cfg.train.rounds;
    let eval_every = trainer.cfg.train.eval_every;
    for t in 1..=rounds {
        let outcome = trainer.run_round()?;
        // post-round bookkeeping is inside run(); we inline it here so the
        // example can print per-round lines.
        let lat = hasfl::latency::round_latency(
            &trainer.profile,
            &trainer.devices,
            &trainer.cfg.server,
            &trainer.dec,
        );
        trainer.sim_time += lat.t_split;
        hasfl::aggregation::aggregate_common(&mut trainer.params, &trainer.dec);
        if t % trainer.cfg.train.agg_interval == 0 {
            hasfl::aggregation::aggregate_forged(&mut trainer.params, &trainer.dec);
            trainer.sim_time += lat.t_agg;
            trainer.dec = trainer.next_decisions();
            println!(
                "  [round {t:>4}] re-optimized: b={:?} cut={:?}",
                trainer.dec.batch, trainer.dec.cut
            );
        }
        let test_acc = if t % eval_every == 0 { Some(trainer.evaluate()?) } else { None };
        if let Some(acc) = test_acc {
            println!(
                "  [round {t:>4}] sim_time {:>8.2}s  loss {:.4}  test_acc {:.2}%",
                trainer.sim_time,
                outcome.mean_loss,
                acc * 100.0
            );
        }
        trainer.history.push(hasfl::metrics::Record {
            round: t,
            sim_time: trainer.sim_time,
            loss: outcome.mean_loss,
            test_acc,
        });
    }

    if let Some((round, time, acc)) = trainer.history.converged_or_last() {
        println!(
            "final: round {round}, simulated {time:.1}s, test accuracy {:.2}%",
            acc * 100.0
        );
    }
    trainer.history.write_csv(std::path::Path::new("results/quickstart.csv"))?;
    println!("loss curve -> results/quickstart.csv");
    trainer.engine.shutdown();
    Ok(())
}
