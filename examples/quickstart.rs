//! Quickstart: the end-to-end driver required by DESIGN.md §7.
//!
//! Trains SplitCNN-8 with the full HASFL stack — Pallas-kernel AOT
//! artifacts through the PJRT runtime, heterogeneity-aware BS+MS
//! re-optimized every I rounds, simulated Table-I edge network — on the
//! synthetic CIFAR-like corpus, driving the step-by-step `Session` API and
//! logging the loss curve + test accuracy.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hasfl::config::StrategyKind;
use hasfl::experiment::{CsvHistory, Experiment, Preset};

fn main() -> hasfl::Result<()> {
    let mut session = Experiment::builder()
        .preset(Preset::Small) // N=4 heterogeneous devices, 200 rounds
        .strategy(StrategyKind::Hasfl)
        .artifacts("artifacts")
        .observe(CsvHistory::new("results/quickstart.csv"))
        .build()?;

    let cfg = session.config();
    println!("HASFL quickstart");
    println!(
        "  fleet: {} devices, {:.1}-{:.1} TFLOPS, uplink {:.0}-{:.0} Mbps",
        cfg.fleet.n_devices,
        cfg.fleet.flops.lo / 1e12,
        cfg.fleet.flops.hi / 1e12,
        cfg.fleet.up_bps.lo / 1e6,
        cfg.fleet.up_bps.hi / 1e6
    );
    println!(
        "  train: {} rounds, I={}, lr={}, eps={}",
        cfg.train.rounds, cfg.train.agg_interval, cfg.train.lr, cfg.train.epsilon
    );
    println!(
        "  initial decisions: b={:?} cut={:?}",
        session.decisions().batch,
        session.decisions().cut
    );

    while !session.is_done() {
        let report = session.step()?;
        if report.reoptimized {
            println!(
                "  [round {:>4}] re-optimized: b={:?} cut={:?}",
                report.round, report.decisions.batch, report.decisions.cut
            );
        }
        if let Some(acc) = report.test_acc {
            println!(
                "  [round {:>4}] sim_time {:>8.2}s  loss {:.4}  test_acc {:.2}%",
                report.round,
                report.sim_time,
                report.outcome.mean_loss,
                acc * 100.0
            );
        }
    }

    if let Some((round, time, acc)) = session.history().converged_or_last() {
        println!(
            "final: round {round}, simulated {time:.1}s, test accuracy {:.2}%",
            acc * 100.0
        );
    }
    session.finish()?; // flushes results/quickstart.csv, stops the engine
    println!("loss curve -> results/quickstart.csv");
    Ok(())
}
