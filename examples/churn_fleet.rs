//! Churn-heavy dynamic fleet, end to end: devices leave, rejoin, and drop
//! out mid-round while channels drift and stragglers strike — the scenario
//! breadth AdaptSFL/ParallelSFL evaluate under and the static fleets of
//! the other examples never exercise.
//!
//! Two halves:
//! 1. Analytic (always runs): `ScenarioSim` over the `churn-heavy` preset —
//!    fleet evolution + drift-triggered BS/MS re-solves + Eqn-38 latency.
//! 2. Executable (always runs — PJRT with AOT artifacts, the native
//!    backend without): a real SplitCNN-8 training session with the same
//!    scenario attached — dropped devices skipped, partial
//!    Eqn-39-weighted aggregation, per-round fleet snapshots.
//!
//! ```bash
//! cargo run --release --example churn_fleet -- [rounds]
//! HASFL_BENCH_SMOKE=1 cargo run --release --example churn_fleet   # CI smoke
//! ```

use hasfl::config::{Config, StrategyKind};
use hasfl::experiment::{Experiment, FleetTraceCsv, Preset};
use hasfl::scenario::{ScenarioPreset, ScenarioSim};

fn main() -> hasfl::Result<()> {
    let smoke = std::env::var("HASFL_BENCH_SMOKE").is_ok();
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(if smoke { 15 } else { 60 })
        .max(1);

    // ---- analytic half (no artifacts needed) -----------------------------
    let mut cfg = Config::table1();
    cfg.fleet.n_devices = 24;
    // Membership changes force re-solves nearly every round; use the
    // latency-greedy benchmark strategy, which stays cheap under churn.
    cfg.strategy = StrategyKind::RbsRhams;
    let spec = ScenarioPreset::ChurnHeavy.scenario();
    println!("churn-heavy analytic sim: N=24 rounds={rounds}");

    let mut sim = ScenarioSim::new(cfg.clone(), spec.clone())?;
    sim.run(rounds);
    let trace = sim.trace();
    let split = trace.split_summary().expect("rounds >= 1");
    println!(
        "  sim_time {:.2}s | partial rounds {} | re-solves {} | t_split p50 {:.4}s p95 {:.4}s",
        sim.sim_time(),
        trace.partial_rounds(),
        trace.resolves(),
        split.p50,
        split.p95
    );

    // Smoke-mode invariants (asserted in CI by ci.sh):
    // determinism — an identical sim replays bit-for-bit;
    let mut replay = ScenarioSim::new(cfg, spec)?;
    replay.run(rounds);
    assert_eq!(trace, replay.trace(), "churn-heavy sim is not deterministic");
    // liveness — every round kept at least one survivor and finite latency;
    for r in &trace.rounds {
        assert!(r.n_active > r.n_dropped, "round {} had no survivors", r.round);
        assert!(r.t_split.is_finite() && r.t_split > 0.0, "round {} latency", r.round);
    }
    // churn actually happened (the preset's whole point).
    let churn_events: usize =
        trace.rounds.iter().map(|r| r.n_joined + r.n_left + r.n_dropped).sum();
    assert!(churn_events > 0, "churn-heavy produced no churn in {rounds} rounds");
    println!("  ok: deterministic replay, {churn_events} churn events, fleet never empty");

    // ---- executable half (resolved backend; never skips) -----------------
    let artifacts = std::path::Path::new("artifacts");
    let exec_rounds = if smoke { 6 } else { 20 };
    let trace_csv = std::env::temp_dir().join("churn_fleet_trace.csv");
    let mut session = Experiment::builder()
        .preset(Preset::Small)
        .devices(4)
        .rounds(exec_rounds)
        .agg_interval(3)
        .eval_every(exec_rounds)
        .scenario_preset(ScenarioPreset::ChurnHeavy)
        .observe(FleetTraceCsv::new(&trace_csv))
        .artifacts(artifacts)
        .build()?;
    println!(
        "churn-heavy executable session: N=4 rounds={exec_rounds} backend={}",
        session.config().backend.as_str()
    );
    while !session.is_done() {
        let report = session.step()?;
        let snap = report.fleet.as_ref().expect("scenario sessions carry snapshots");
        println!(
            "  round {:>3}: active {} dropped {:?} drift {:.3} loss {:.4}{}",
            report.round,
            snap.active.len(),
            snap.dropped,
            snap.drift,
            report.outcome.mean_loss,
            if report.reoptimized { "  [re-solved]" } else { "" }
        );
        assert!(report.outcome.mean_loss.is_finite());
    }
    session.finish()?;
    println!("  fleet trace -> {}", trace_csv.display());
    Ok(())
}
