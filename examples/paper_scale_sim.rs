//! Paper-scale simulation walkthrough: N=20 Table-I devices, VGG-16 and
//! ResNet-18 analytic profiles. Shows what HASFL's optimizer actually
//! decides at the paper's operating point — the per-device batch/cut
//! table, the latency breakdown, and the predicted round budget R(ε) —
//! and contrasts it with uniform configurations.
//!
//! ```bash
//! cargo run --release --example paper_scale_sim
//! ```

use hasfl::config::Config;
use hasfl::convergence::{rounds_to_epsilon, BoundParams};
use hasfl::experiment::Experiment;
use hasfl::latency::{round_latency, total_latency, Decisions};
use hasfl::model::ModelProfile;
use hasfl::optimizer::{solve_joint, OptContext};
use hasfl::rng::Pcg32;

fn main() -> hasfl::Result<()> {
    for profile in [ModelProfile::vgg16(), ModelProfile::resnet18()] {
        // Validated analytic config: no artifacts or engine needed.
        let cfg = Experiment::builder().config(Config::table1()).build_config()?;
        let bound = BoundParams::default_for(&profile, cfg.train.lr);
        let devices = cfg.sample_fleet();
        let ctx = OptContext {
            profile: &profile,
            devices: &devices,
            server: &cfg.server,
            bound: &bound,
            interval: cfg.train.agg_interval,
            epsilon: cfg.train.epsilon,
            batch_cap: cfg.train.batch_cap,
        };
        let mut rng = Pcg32::seeded(cfg.seed);
        let sol = solve_joint(&ctx, &mut rng, 8, 1e-6);

        println!("=== {} (L = {} layers) ===", profile.name, profile.n_layers());
        println!("HASFL decisions (Algorithm 2):");
        println!("  batches: {:?}", sol.decisions.batch);
        println!("  cuts:    {:?}", sol.decisions.cut);
        let lat = round_latency(&profile, &devices, &cfg.server, &sol.decisions);
        let r = rounds_to_epsilon(
            &bound,
            &sol.decisions.batch,
            sol.decisions.l_c(),
            cfg.train.agg_interval,
            cfg.train.epsilon,
        )
        .unwrap();
        println!(
            "  T_S {:.3}s  T_A {:.3}s  R(eps) {:.0} rounds  est. total {:.2}h",
            lat.t_split,
            lat.t_agg,
            r,
            total_latency(&lat, r as usize, cfg.train.agg_interval) / 3600.0
        );

        println!("uniform baselines:");
        for (b, cut) in [(16u32, 4usize), (16, 8), (64, 8)] {
            let dec = Decisions::uniform(devices.len(), b, cut);
            match ctx.objective(&dec) {
                Some(v) => println!("  b={b:<3} cut={cut:<3} -> est. {:.2}h", v / 3600.0),
                None => println!("  b={b:<3} cut={cut:<3} -> infeasible"),
            }
        }
        println!(
            "HASFL predicted speedup vs uniform(16,8): {:.2}x\n",
            ctx.objective(&Decisions::uniform(devices.len(), 16, 8))
                .map(|v| v / sol.theta)
                .unwrap_or(f64::NAN)
        );
    }
    Ok(())
}
